//! The wire-protocol frame codec, shared by both front-ends (the
//! thread-per-connection pipeline in [`super::server`] and the epoll
//! event loop in [`super::reactor`]), so the two backends cannot drift:
//! one parser, one reply formatter, one framing state machine.
//!
//! ## Protocol (line-oriented text)
//!
//! ```text
//! G <k>        get            → reply line: "<v>" or "-"
//! P <k> <v>    put (insert)   → previous "<v>" or "-"
//! D <k>        delete         → removed "<v>" or "-"
//! U <k> <v>    get-or-insert  → pre-existing "<v>", or "-" (inserted)
//! A <k> <d>    fetch-add      → previous "<v>", or "-" (was absent,
//!              now holds d; missing keys count as 0)
//! C <k> <e> <n>  compare-exchange; <e>/<n> are a value or "-"
//!              (absent) — the four corners of
//!              ConcurrentMap::compare_exchange → "OK" on commit,
//!              "!<v>" / "!-" with the witnessed value on failure
//! B <n>        batch frame: the next n lines are ops (any of the
//!              above); one reply line with n space-separated tokens
//! T <n>        transaction frame: same body grammar as `B <n>`, but
//!              the n ops commit atomically (all-or-nothing) via
//!              ConcurrentMap::apply_txn — one reply line with n
//!              space-separated tokens on commit, or a single
//!              "ERR txn conflict" / "ERR txn unsupported" line when
//!              the commit aborts (nothing is applied)
//! STATS        telemetry snapshot → one line of compact JSON (see
//!              [`crate::util::metrics::stats_line`])
//! Q            quit (close the connection)
//! ```
//!
//! Malformed or out-of-range requests get an `ERR <msg>` line and the
//! connection **stays up** — keys outside `[1, MAX_KEY]` are rejected
//! at the protocol boundary with `ERR key out of range` instead of
//! tripping the table's `check_key` assert, and values (including `C`
//! operands and `A` deltas) above `kcas::MAX_VALUE` get
//! `ERR value out of range`. A batch frame is validated as a unit: if
//! any member op is invalid the whole frame is rejected with a single
//! `ERR` line and nothing is applied.
//!
//! [`FrameDecoder`] is the *incremental* face of the same grammar: it
//! is fed raw bytes as `read()` hands them over — frames split across
//! arbitrary read boundaries, partial lines, many frames per read —
//! and yields complete [`Frame`]s. The blocking server wraps it over a
//! blocking read loop; the reactor feeds it from nonblocking reads.

use std::fmt::Write as _;

use crate::kcas::MAX_VALUE;
use crate::maps::{MapOp, MapReply, MAX_KEY};
use crate::util::metrics::metrics;

/// Largest accepted batch frame (bounds per-connection memory).
pub const MAX_BATCH: usize = 4096;

/// Longest accepted request line, in bytes (bounds decoder memory
/// against a newline-less flood). Generous: the longest legal line is
/// a `C` op with two 19-digit operands, ~70 bytes.
pub const MAX_LINE: usize = 4096;

pub const ERR_KEY_RANGE: &str = "ERR key out of range";
pub const ERR_VALUE_RANGE: &str = "ERR value out of range";
pub const ERR_BAD_REQUEST: &str = "ERR bad request";
pub const ERR_BAD_BATCH: &str = "ERR bad batch size";
pub const ERR_SERVER: &str = "ERR server error";
/// A `T <n>` frame aborted after the bounded structural-conflict
/// retry budget ([`crate::maps::MapError::TxnConflict`]); nothing was
/// applied and the client may retry.
pub const ERR_TXN_CONFLICT: &str = "ERR txn conflict";
/// The serving table has no transaction protocol
/// ([`crate::maps::MapError::Unsupported`] — e.g. the `tx-rh`
/// baseline); nothing was applied.
pub const ERR_TXN_UNSUPPORTED: &str = "ERR txn unsupported";

fn parse_key(s: &str) -> Result<u64, &'static str> {
    let k: u64 = s.parse().map_err(|_| ERR_BAD_REQUEST)?;
    if !(1..=MAX_KEY).contains(&k) {
        return Err(ERR_KEY_RANGE);
    }
    Ok(k)
}

fn parse_value(s: &str) -> Result<u64, &'static str> {
    let v: u64 = s.parse().map_err(|_| ERR_BAD_REQUEST)?;
    if v > MAX_VALUE {
        return Err(ERR_VALUE_RANGE);
    }
    Ok(v)
}

/// `C` operand: a value or `-` for "absent".
fn parse_opt_value(s: &str) -> Result<Option<u64>, &'static str> {
    if s == "-" {
        return Ok(None);
    }
    parse_value(s).map(Some)
}

/// Parse one op line (`G <k>` / `P <k> <v>` / `D <k>` / `U <k> <v>` /
/// `A <k> <d>` / `C <k> <e> <n>`), enforcing the key and value ranges
/// at the protocol boundary. Trailing garbage (extra tokens) rejects
/// the line.
pub fn parse_op(line: &str) -> Result<MapOp, &'static str> {
    let mut it = line.split_whitespace();
    let toks = [it.next(), it.next(), it.next(), it.next(), it.next()];
    match toks {
        [Some("G"), Some(k), None, None, None] => {
            Ok(MapOp::Get(parse_key(k)?))
        }
        [Some("D"), Some(k), None, None, None] => {
            Ok(MapOp::Remove(parse_key(k)?))
        }
        [Some("P"), Some(k), Some(v), None, None] => {
            Ok(MapOp::Insert(parse_key(k)?, parse_value(v)?))
        }
        [Some("U"), Some(k), Some(v), None, None] => {
            Ok(MapOp::GetOrInsert(parse_key(k)?, parse_value(v)?))
        }
        [Some("A"), Some(k), Some(d), None, None] => {
            Ok(MapOp::FetchAdd(parse_key(k)?, parse_value(d)?))
        }
        [Some("C"), Some(k), Some(e), Some(n), None] => Ok(MapOp::CmpEx(
            parse_key(k)?,
            parse_opt_value(e)?,
            parse_opt_value(n)?,
        )),
        _ => Err(ERR_BAD_REQUEST),
    }
}

/// Append one reply token: the value or `-` for value-shaped replies,
/// `OK` / `!<witness>` / `!-` for `CmpEx`.
pub fn push_reply(reply: MapReply, out: &mut String) {
    match reply {
        MapReply::CmpEx(Ok(())) => out.push_str("OK"),
        MapReply::CmpEx(Err(w)) => {
            out.push('!');
            match w {
                Some(v) => write!(out, "{v}").expect("write to String"),
                None => out.push('-'),
            }
        }
        _ => match reply.value() {
            Some(v) => write!(out, "{v}").expect("write to String"),
            None => out.push('-'),
        },
    }
}

/// Append one op in wire format (plus newline) — the client-side
/// inverse of [`parse_op`].
pub fn push_op(op: MapOp, out: &mut String) {
    let opt = |v: Option<u64>| match v {
        Some(v) => v.to_string(),
        None => "-".into(),
    };
    match op {
        MapOp::Get(k) => writeln!(out, "G {k}"),
        MapOp::Insert(k, v) => writeln!(out, "P {k} {v}"),
        MapOp::Remove(k) => writeln!(out, "D {k}"),
        MapOp::GetOrInsert(k, v) => writeln!(out, "U {k} {v}"),
        MapOp::FetchAdd(k, d) => writeln!(out, "A {k} {d}"),
        MapOp::CmpEx(k, e, n) => writeln!(out, "C {k} {} {}", opt(e), opt(n)),
    }
    .expect("write to String");
}

/// One parsed request frame.
#[derive(Debug, PartialEq, Eq)]
pub enum Frame {
    /// Ops to apply with a single `apply_batch` call.
    Batch(Vec<MapOp>),
    /// Ops to commit atomically with a single `apply_txn` call
    /// (`T <n>` frame). All-or-nothing: on conflict or an unsupported
    /// table the reply is one `ERR` line (see [`txn_err_line`]) and
    /// nothing is applied.
    Txn(Vec<MapOp>),
    /// Client asked for a telemetry snapshot (`STATS`); the reply is
    /// one line of compact JSON. Only valid as a bare line — inside a
    /// `B <n>` / `T <n>` body it is an ordinary unparseable member.
    Stats,
    /// Protocol error to report; nothing is applied.
    Err(&'static str),
    /// Client said `Q`.
    Quit,
}

/// The single `ERR` reply line for a failed `T <n>` commit — shared by
/// all front-ends so transaction failures are byte-identical across
/// backends. Conflict and unsupported get their dedicated lines;
/// anything else (a table-full plan, say) reports as a generic server
/// error rather than inventing per-cause wire vocabulary.
pub fn txn_err_line(e: &crate::maps::MapError) -> &'static str {
    use crate::maps::MapError;
    match e {
        MapError::TxnConflict => ERR_TXN_CONFLICT,
        MapError::Unsupported => ERR_TXN_UNSUPPORTED,
        _ => ERR_SERVER,
    }
}

/// One step of line extraction (see [`FrameDecoder::take_line`]).
enum LineStep {
    /// A complete line: `buf[start..end]` (newline excluded).
    Line(usize, usize),
    /// An over-long line to report as one `ERR bad request`.
    Report,
    /// Consumed bytes with nothing to report (over-long-line tail).
    Skip,
}

/// A partially-received `B <n>` / `T <n>` frame: member lines seen so
/// far.
struct PendingBatch {
    remaining: usize,
    ops: Vec<MapOp>,
    /// First member parse error — the whole frame is rejected, but the
    /// stream keeps consuming all `n` member lines to stay in sync.
    err: Option<&'static str>,
    /// True for a `T <n>` header: the completed body decodes as
    /// [`Frame::Txn`] instead of [`Frame::Batch`].
    txn: bool,
}

/// Incremental frame decoder: [`FrameDecoder::feed`] it raw bytes in
/// whatever chunks the transport delivers, then drain complete frames
/// with [`FrameDecoder::next_frame`]. Both front-ends speak exactly
/// this state machine, so reply streams are bit-identical no matter
/// how the request bytes were fragmented.
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted on feed).
    pos: usize,
    pending: Option<PendingBatch>,
    /// Set while skipping an over-[`MAX_LINE`] line to its newline; the
    /// line decodes as one `ERR bad request` once the newline arrives
    /// (same observable as the unbounded blocking reader, but with
    /// bounded memory).
    discarding: bool,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Hand the decoder the next chunk of received bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes fed but not yet consumed by a completed line.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when buffered bytes may still hold a complete frame —
    /// i.e. at least one full line is waiting to be decoded.
    pub fn has_complete_line(&self) -> bool {
        self.buf[self.pos..].contains(&b'\n')
    }

    /// Next complete line as a `buf` range, the one-time overflow
    /// report for an over-[`MAX_LINE`] line, or the silent skip of such
    /// a line's already-reported tail.
    fn take_line(&mut self) -> Option<LineStep> {
        let rest = &self.buf[self.pos..];
        match rest.iter().position(|&b| b == b'\n') {
            Some(nl) => {
                let (start, end) = (self.pos, self.pos + nl);
                self.pos = end + 1;
                if self.discarding {
                    // Tail of an over-long line, reported at overflow
                    // time: swallow it silently.
                    self.discarding = false;
                    return Some(LineStep::Skip);
                }
                Some(LineStep::Line(start, end))
            }
            None if rest.len() > MAX_LINE => {
                // No newline in sight and the line is already over
                // budget: drop what we have, discard to the newline,
                // and report the line once.
                self.pos = self.buf.len();
                if self.discarding {
                    return Some(LineStep::Skip); // already reported
                }
                self.discarding = true;
                Some(LineStep::Report)
            }
            None => None,
        }
    }

    /// EOF: decode the final unterminated line, if any. A blocking
    /// `read_line` reader hands back the last line even without a
    /// trailing newline, and clients really do end streams with
    /// `printf 'G 5' |` — so both front-ends answer it. Implemented by
    /// terminating whatever is buffered with a synthetic newline; a
    /// truncated batch body (fewer member lines than promised) still
    /// yields nothing, exactly like the blocking reader. Idempotent
    /// once the buffer is drained.
    pub fn finish(&mut self) -> Option<Frame> {
        if self.buffered() == 0 && !self.discarding {
            return None;
        }
        self.feed(b"\n");
        self.next_frame()
    }

    /// Decode the next complete frame, if the buffered bytes contain
    /// one. `None` means "feed me more bytes" — a partially received
    /// line or batch body stays buffered.
    pub fn next_frame(&mut self) -> Option<Frame> {
        let frame = self.next_frame_inner()?;
        let m = metrics();
        m.frames_decoded.incr();
        if let Frame::Batch(ops) = &frame {
            m.batch_size.record(ops.len() as u64);
        }
        Some(frame)
    }

    fn next_frame_inner(&mut self) -> Option<Frame> {
        loop {
            let line = match self.take_line()? {
                LineStep::Line(start, end) => &self.buf[start..end],
                LineStep::Skip => continue,
                LineStep::Report => {
                    // Over-long line: one bad-request report. Inside a
                    // batch body it poisons the frame as a member.
                    match self.pending.as_mut() {
                        Some(p) => {
                            p.err = p.err.or(Some(ERR_BAD_REQUEST));
                            p.remaining -= 1;
                            if p.remaining > 0 {
                                continue;
                            }
                            let p = self.pending.take().expect("pending");
                            return Some(Frame::Err(
                                p.err.unwrap_or(ERR_BAD_REQUEST),
                            ));
                        }
                        None => return Some(Frame::Err(ERR_BAD_REQUEST)),
                    }
                }
            };
            // The protocol is ASCII; a non-UTF-8 line can't parse, so
            // treat it as any other malformed line.
            let head = std::str::from_utf8(line).unwrap_or("\u{fffd}").trim();

            if let Some(p) = self.pending.as_mut() {
                // Member line of a `B <n>` body (any line counts, even
                // empty or `Q` — the body length was promised).
                match parse_op(head) {
                    Ok(op) => p.ops.push(op),
                    Err(e) => p.err = p.err.or(Some(e)),
                }
                p.remaining -= 1;
                if p.remaining > 0 {
                    continue;
                }
                let p = self.pending.take().expect("pending");
                return Some(match (p.err, p.txn) {
                    (Some(e), _) => Frame::Err(e),
                    (None, false) => Frame::Batch(p.ops),
                    (None, true) => Frame::Txn(p.ops),
                });
            }

            if head.is_empty() {
                continue;
            }
            if head == "Q" {
                return Some(Frame::Quit);
            }
            if head == "STATS" {
                return Some(Frame::Stats);
            }
            let header = head
                .strip_prefix("B ")
                .map(|rest| (rest, false))
                .or_else(|| head.strip_prefix("T ").map(|rest| (rest, true)));
            if let Some((rest, txn)) = header {
                match rest.trim().parse::<usize>() {
                    Ok(n) if (1..=MAX_BATCH).contains(&n) => {
                        self.pending = Some(PendingBatch {
                            remaining: n,
                            ops: Vec::with_capacity(n),
                            err: None,
                            txn,
                        });
                        continue;
                    }
                    _ => return Some(Frame::Err(ERR_BAD_BATCH)),
                }
            }
            return Some(match parse_op(head) {
                Ok(op) => Frame::Batch(vec![op]),
                Err(e) => Frame::Err(e),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(dec: &mut FrameDecoder) -> Vec<Frame> {
        std::iter::from_fn(|| dec.next_frame()).collect()
    }

    fn decode_whole(input: &str) -> Vec<Frame> {
        let mut dec = FrameDecoder::new();
        dec.feed(input.as_bytes());
        drain(&mut dec)
    }

    #[test]
    fn parse_op_accepts_valid_lines() {
        assert_eq!(parse_op("G 5"), Ok(MapOp::Get(5)));
        assert_eq!(parse_op("P 5 10"), Ok(MapOp::Insert(5, 10)));
        assert_eq!(parse_op("D 5"), Ok(MapOp::Remove(5)));
        assert_eq!(parse_op("  G   5  "), Ok(MapOp::Get(5)));
        assert_eq!(parse_op(&format!("G {MAX_KEY}")), Ok(MapOp::Get(MAX_KEY)));
        assert_eq!(
            parse_op(&format!("P 1 {MAX_VALUE}")),
            Ok(MapOp::Insert(1, MAX_VALUE))
        );
    }

    #[test]
    fn parse_op_rejects_out_of_range_keys() {
        // The original server's DoS: any k >= 1 was forwarded to the
        // table, and k > MAX_KEY tripped check_key's assert.
        assert_eq!(parse_op(&format!("G {}", MAX_KEY + 1)), Err(ERR_KEY_RANGE));
        assert_eq!(parse_op("G 0"), Err(ERR_KEY_RANGE));
        assert_eq!(parse_op(&format!("P {} 1", u64::MAX)), Err(ERR_KEY_RANGE));
        assert_eq!(parse_op("D 0"), Err(ERR_KEY_RANGE));
        assert_eq!(
            parse_op(&format!("P 1 {}", MAX_VALUE + 1)),
            Err(ERR_VALUE_RANGE)
        );
    }

    #[test]
    fn parse_op_rejects_malformed_lines() {
        for bad in [
            "", "G", "P 1", "G x", "P 1 y", "X 1", "G 1 2", "P 1 2 3", "Q 1",
        ] {
            assert_eq!(parse_op(bad), Err(ERR_BAD_REQUEST), "line {bad:?}");
        }
    }

    #[test]
    fn parse_op_accepts_conditional_verbs() {
        assert_eq!(parse_op("U 5 10"), Ok(MapOp::GetOrInsert(5, 10)));
        assert_eq!(parse_op("A 5 3"), Ok(MapOp::FetchAdd(5, 3)));
        assert_eq!(parse_op("C 5 - 10"), Ok(MapOp::CmpEx(5, None, Some(10))));
        assert_eq!(parse_op("C 5 10 -"), Ok(MapOp::CmpEx(5, Some(10), None)));
        assert_eq!(
            parse_op("C 5 10 11"),
            Ok(MapOp::CmpEx(5, Some(10), Some(11)))
        );
        assert_eq!(parse_op("C 5 - -"), Ok(MapOp::CmpEx(5, None, None)));
        // Range / shape enforcement.
        assert_eq!(
            parse_op(&format!("A 5 {}", MAX_VALUE + 1)),
            Err(ERR_VALUE_RANGE)
        );
        assert_eq!(
            parse_op(&format!("C 5 - {}", MAX_VALUE + 1)),
            Err(ERR_VALUE_RANGE)
        );
        assert_eq!(parse_op("C 0 - 1"), Err(ERR_KEY_RANGE));
        for bad in ["U 5", "A 5", "C 5 -", "C 5 - - -", "C 5 x 1", "U 5 1 2"] {
            assert_eq!(parse_op(bad), Err(ERR_BAD_REQUEST), "line {bad:?}");
        }
    }

    #[test]
    fn cmpex_reply_tokens() {
        let mut s = String::new();
        push_reply(MapReply::CmpEx(Ok(())), &mut s);
        s.push(' ');
        push_reply(MapReply::CmpEx(Err(Some(7))), &mut s);
        s.push(' ');
        push_reply(MapReply::CmpEx(Err(None)), &mut s);
        s.push(' ');
        push_reply(MapReply::Existing(None), &mut s);
        s.push(' ');
        push_reply(MapReply::Added(Some(3)), &mut s);
        assert_eq!(s, "OK !7 !- - 3");
    }

    #[test]
    fn reply_tokens_round_trip() {
        let mut s = String::new();
        push_reply(MapReply::Value(Some(42)), &mut s);
        s.push(' ');
        push_reply(MapReply::Prev(None), &mut s);
        s.push(' ');
        push_reply(MapReply::Removed(Some(7)), &mut s);
        assert_eq!(s, "42 - 7");
    }

    #[test]
    fn decoder_yields_frames_in_order() {
        let frames = decode_whole("G 1\nB 2\nP 2 20\nG 2\nD 2\nQ\n");
        assert_eq!(
            frames,
            vec![
                Frame::Batch(vec![MapOp::Get(1)]),
                Frame::Batch(vec![MapOp::Insert(2, 20), MapOp::Get(2)]),
                Frame::Batch(vec![MapOp::Remove(2)]),
                Frame::Quit,
            ]
        );
    }

    #[test]
    fn decoder_handles_arbitrary_split_boundaries() {
        let input = "P 7 70\nB 3\nG 7\nC 7 70 71\nA 7 2\nnonsense\nB 0\nQ\n";
        let whole = decode_whole(input);
        // Byte-at-a-time delivery must produce the identical stream.
        for chunk in 1..=7usize {
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            for piece in input.as_bytes().chunks(chunk) {
                dec.feed(piece);
                got.extend(std::iter::from_fn(|| dec.next_frame()));
            }
            assert_eq!(got, whole, "chunk size {chunk}");
        }
        assert_eq!(whole.len(), 5);
        assert_eq!(whole[2], Frame::Err(ERR_BAD_REQUEST));
        assert_eq!(whole[3], Frame::Err(ERR_BAD_BATCH));
        assert_eq!(whole[4], Frame::Quit);
    }

    #[test]
    fn decoder_holds_incomplete_frames() {
        let mut dec = FrameDecoder::new();
        dec.feed(b"G ");
        assert_eq!(dec.next_frame(), None);
        dec.feed(b"5\nB 2\nG 1\n");
        assert_eq!(dec.next_frame(), Some(Frame::Batch(vec![MapOp::Get(5)])));
        // Batch body short one line: nothing until it arrives.
        assert_eq!(dec.next_frame(), None);
        dec.feed(b"G 2\n");
        assert_eq!(
            dec.next_frame(),
            Some(Frame::Batch(vec![MapOp::Get(1), MapOp::Get(2)]))
        );
        assert_eq!(dec.next_frame(), None);
        assert_eq!(dec.buffered(), 0);
    }

    #[test]
    fn decoder_rejects_oversized_batch_counts() {
        // Over-MAX_BATCH header: one ERR, no body consumed — following
        // lines are ordinary frames.
        let frames = decode_whole(&format!("B {}\nG 1\n", MAX_BATCH + 1));
        assert_eq!(
            frames,
            vec![
                Frame::Err(ERR_BAD_BATCH),
                Frame::Batch(vec![MapOp::Get(1)]),
            ]
        );
        assert_eq!(
            decode_whole("B 18446744073709551616\n"), // u64::MAX + 1
            vec![Frame::Err(ERR_BAD_BATCH)]
        );
        assert_eq!(decode_whole("B x\n"), vec![Frame::Err(ERR_BAD_BATCH)]);
    }

    #[test]
    fn decoder_rejects_bad_batch_member_as_a_unit() {
        // One bad member rejects the frame but consumes the whole body,
        // keeping the stream in sync for the next frame.
        let frames = decode_whole("B 3\nP 1 10\nG 0\nP 2 20\nG 1\n");
        assert_eq!(
            frames,
            vec![
                Frame::Err(ERR_KEY_RANGE),
                Frame::Batch(vec![MapOp::Get(1)]),
            ]
        );
    }

    #[test]
    fn decoder_rejects_trailing_garbage_after_frames() {
        // Extra tokens after a complete op are a parse error...
        assert_eq!(decode_whole("G 1 junk\n"), vec![Frame::Err(ERR_BAD_REQUEST)]);
        // ...and garbage lines after a complete batch are their own
        // (failed) frame, not silently absorbed into the previous one.
        let frames = decode_whole("B 1\nG 1\ngarbage here\nG 2\n");
        assert_eq!(
            frames,
            vec![
                Frame::Batch(vec![MapOp::Get(1)]),
                Frame::Err(ERR_BAD_REQUEST),
                Frame::Batch(vec![MapOp::Get(2)]),
            ]
        );
    }

    #[test]
    fn decoder_bounds_memory_on_newlineless_floods() {
        let mut dec = FrameDecoder::new();
        // A newline-less flood far past MAX_LINE: reported once as a
        // bad request, buffered bytes stay bounded.
        for _ in 0..64 {
            dec.feed(&[b'x'; 1024]);
        }
        assert_eq!(dec.next_frame(), Some(Frame::Err(ERR_BAD_REQUEST)));
        assert_eq!(dec.next_frame(), None);
        assert!(dec.buffered() <= 2 * MAX_LINE, "buffered {}", dec.buffered());
        // Once the newline finally lands, the stream resynchronizes.
        dec.feed(b"y\nG 3\n");
        assert_eq!(dec.next_frame(), Some(Frame::Batch(vec![MapOp::Get(3)])));
        assert_eq!(dec.next_frame(), None);
    }

    #[test]
    fn finish_answers_unterminated_final_line() {
        // `printf 'G 5' |` clients: the last line arrives without a
        // newline, then EOF — it still decodes.
        let mut dec = FrameDecoder::new();
        dec.feed(b"P 5 50\nG 5");
        assert_eq!(
            dec.next_frame(),
            Some(Frame::Batch(vec![MapOp::Insert(5, 50)]))
        );
        assert_eq!(dec.next_frame(), None);
        assert_eq!(dec.finish(), Some(Frame::Batch(vec![MapOp::Get(5)])));
        // Idempotent once drained.
        assert_eq!(dec.finish(), None);
        assert_eq!(dec.buffered(), 0);

        // An unterminated final member line completes its batch...
        let mut dec = FrameDecoder::new();
        dec.feed(b"B 2\nG 1\nG 2");
        assert_eq!(dec.next_frame(), None);
        assert_eq!(
            dec.finish(),
            Some(Frame::Batch(vec![MapOp::Get(1), MapOp::Get(2)]))
        );
        // ...but a truncated body (missing member lines) still yields
        // nothing, like the blocking reader.
        let mut dec = FrameDecoder::new();
        dec.feed(b"B 3\nG 1\nG 2");
        assert_eq!(dec.next_frame(), None);
        assert_eq!(dec.finish(), None);

        // Whitespace-only and quit tails.
        let mut dec = FrameDecoder::new();
        dec.feed(b"  ");
        assert_eq!(dec.finish(), None);
        let mut dec = FrameDecoder::new();
        dec.feed(b"Q");
        assert_eq!(dec.finish(), Some(Frame::Quit));
    }

    #[test]
    fn decoder_yields_stats_frames_only_as_bare_lines() {
        // Bare STATS is its own frame, in stream order.
        let frames = decode_whole("G 1\nSTATS\nQ\n");
        assert_eq!(
            frames,
            vec![
                Frame::Batch(vec![MapOp::Get(1)]),
                Frame::Stats,
                Frame::Quit,
            ]
        );
        // Inside a batch body it is an unparseable member: the whole
        // frame is rejected and the stream stays in sync.
        let frames = decode_whole("B 2\nG 1\nSTATS\nG 2\n");
        assert_eq!(
            frames,
            vec![
                Frame::Err(ERR_BAD_REQUEST),
                Frame::Batch(vec![MapOp::Get(2)]),
            ]
        );
        // Split across arbitrary read boundaries it still decodes.
        let input = "STATS\nG 3\nSTATS\n";
        let whole = decode_whole(input);
        for chunk in 1..=4usize {
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            for piece in input.as_bytes().chunks(chunk) {
                dec.feed(piece);
                got.extend(std::iter::from_fn(|| dec.next_frame()));
            }
            assert_eq!(got, whole, "chunk size {chunk}");
        }
        // Unterminated STATS at EOF decodes like any final line.
        let mut dec = FrameDecoder::new();
        dec.feed(b"STATS");
        assert_eq!(dec.finish(), Some(Frame::Stats));
    }

    #[test]
    fn decoder_yields_txn_frames() {
        // T <n> shares the batch body grammar but decodes as Txn.
        let frames = decode_whole("T 3\nG 1\nC 1 - 5\nA 2 1\nB 1\nG 2\nQ\n");
        assert_eq!(
            frames,
            vec![
                Frame::Txn(vec![
                    MapOp::Get(1),
                    MapOp::CmpEx(1, None, Some(5)),
                    MapOp::FetchAdd(2, 1),
                ]),
                Frame::Batch(vec![MapOp::Get(2)]),
                Frame::Quit,
            ]
        );
        // Header bounds match B <n> exactly.
        assert_eq!(decode_whole("T 0\n"), vec![Frame::Err(ERR_BAD_BATCH)]);
        assert_eq!(
            decode_whole(&format!("T {}\n", MAX_BATCH + 1)),
            vec![Frame::Err(ERR_BAD_BATCH)]
        );
        assert_eq!(decode_whole("T x\n"), vec![Frame::Err(ERR_BAD_BATCH)]);
        // A bad member rejects the whole frame and nothing is applied,
        // but the body is consumed so the stream stays in sync.
        let frames = decode_whole("T 2\nG 0\nG 1\nG 2\n");
        assert_eq!(
            frames,
            vec![
                Frame::Err(ERR_KEY_RANGE),
                Frame::Batch(vec![MapOp::Get(2)]),
            ]
        );
    }

    #[test]
    fn txn_frames_decode_identically_across_split_boundaries() {
        let input = "T 2\nP 1 10\nD 2\nT 1\nG 1\nQ\n";
        let whole = decode_whole(input);
        assert_eq!(
            whole,
            vec![
                Frame::Txn(vec![MapOp::Insert(1, 10), MapOp::Remove(2)]),
                Frame::Txn(vec![MapOp::Get(1)]),
                Frame::Quit,
            ]
        );
        for chunk in 1..=5usize {
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            for piece in input.as_bytes().chunks(chunk) {
                dec.feed(piece);
                got.extend(std::iter::from_fn(|| dec.next_frame()));
            }
            assert_eq!(got, whole, "chunk size {chunk}");
        }
        // Unterminated final member completes via finish(), like B.
        let mut dec = FrameDecoder::new();
        dec.feed(b"T 1\nG 7");
        assert_eq!(dec.next_frame(), None);
        assert_eq!(dec.finish(), Some(Frame::Txn(vec![MapOp::Get(7)])));
    }

    #[test]
    fn txn_err_lines_are_stable() {
        use crate::maps::MapError;
        assert_eq!(txn_err_line(&MapError::TxnConflict), ERR_TXN_CONFLICT);
        assert_eq!(txn_err_line(&MapError::Unsupported), ERR_TXN_UNSUPPORTED);
        assert_eq!(txn_err_line(&MapError::TableFull), ERR_SERVER);
        assert_eq!(txn_err_line(&MapError::Frozen), ERR_SERVER);
    }

    #[test]
    fn decoder_skips_blank_lines_between_frames() {
        let frames = decode_whole("\n  \nG 1\n\nQ\n");
        assert_eq!(
            frames,
            vec![Frame::Batch(vec![MapOp::Get(1)]), Frame::Quit]
        );
    }
}
