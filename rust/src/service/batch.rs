//! Batched operation API (ROADMAP "Batched / async API" milestone).
//!
//! [`apply_batch`] is the service-layer entry point: one call applies a
//! slice of [`MapOp`]s and yields one [`MapReply`] per op, in op order,
//! observably equivalent to applying them one at a time. What batching
//! buys is *amortisation*, at two levels:
//!
//! * the inner `KCasRobinHoodMap` borrows its thread-local
//!   `OpBuilder`/scratch once per **batch** instead of once per op
//!   (see `KCasRobinHoodMap::apply_batch_local`);
//! * the `Sharded` facade groups a batch by shard and forwards each
//!   group as one contiguous sub-batch, so the amortisation survives
//!   sharding (and a networked front-end — `service::server` — gets
//!   frame-level syscall amortisation on top).
//!
//! This module also hosts the map-workload plumbing shared by the
//! `fig14_batching` experiment: [`prefill_map`], [`map_op`], and the
//! timed driver [`run_batched`] (the key→value sibling of
//! `bench::driver::run_prefilled`) — plus [`run_rmw`], the
//! conditional-RMW counter-workload driver behind `fig16_rmw`, which
//! doubles as an atomicity harness (committed increments must equal
//! the final counter sum).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Barrier;
use std::time::{Duration, Instant};

use crate::bench::driver::RunResult;
use crate::bench::workload::{Op, WorkloadCfg};
use crate::maps::{ConcurrentMap, MapOp, MapReply};
use crate::util::affinity;
use crate::util::rng::Rng;

/// Apply a batch of operations, returning one reply per op in op order.
///
/// Convenience wrapper over [`ConcurrentMap::apply_batch`] for callers
/// that don't manage a reusable reply buffer; hot paths (the server
/// pipeline, the bench driver) call the trait method directly with a
/// long-lived `Vec`.
pub fn apply_batch(map: &dyn ConcurrentMap, ops: &[MapOp]) -> Vec<MapReply> {
    let mut out = Vec::with_capacity(ops.len());
    map.apply_batch(ops, &mut out);
    out
}

/// Lift a set-benchmark op onto the map workload. Inserted values
/// encode the key (`value == key`), which keeps the paper's workload
/// generator reusable and lets stress tests detect torn pairs.
#[inline]
pub fn map_op(op: Op) -> MapOp {
    match op {
        Op::Contains(k) => MapOp::Get(k),
        Op::Add(k) => MapOp::Insert(k, k),
        Op::Remove(k) => MapOp::Remove(k),
    }
}

/// Prefill `map` to the configured load factor (the key→value sibling
/// of `bench::workload::prefill`; same deterministic key stream).
pub fn prefill_map(map: &dyn ConcurrentMap, cfg: &WorkloadCfg) -> usize {
    let n = cfg.prefill_count();
    let space = cfg.key_space();
    let mut rng = Rng::new(cfg.seed ^ 0xDEAD_BEEF);
    let mut added = 0;
    while added < n {
        let key = 1 + rng.below(space);
        if map.insert(key, key).is_none() {
            added += 1;
        }
    }
    added
}

/// Timed batched benchmark cell: every thread assembles `batch` ops
/// from the workload mix and applies them with a single
/// [`ConcurrentMap::apply_batch`] call. `batch == 0` is the unbatched
/// baseline (direct `get`/`insert`/`remove` calls, one scratch borrow
/// per op) that `fig14_batching` compares against.
pub fn run_batched(
    map: &dyn ConcurrentMap,
    cfg: &WorkloadCfg,
    threads: usize,
    batch: usize,
    pin: bool,
) -> RunResult {
    let stop = AtomicBool::new(false);
    let barrier = Barrier::new(threads + 1);
    let mut slots = vec![(0u64, 0u64); threads];

    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (idx, slot) in slots.iter_mut().enumerate() {
            let stop = &stop;
            let barrier = &barrier;
            handles.push(s.spawn(move || {
                if pin {
                    affinity::pin_thread(idx);
                }
                let mut rng = Rng::for_thread(cfg.seed, idx as u64);
                let mut ops_buf: Vec<MapOp> = Vec::with_capacity(batch.max(1));
                let mut replies: Vec<MapReply> =
                    Vec::with_capacity(batch.max(1));
                barrier.wait();
                // Per-worker measurement window, as in
                // `bench::driver::run_prefilled`.
                let t0 = Instant::now();
                let mut ops = 0u64;
                // ORDERING: eventual-visibility stop flag, as in
                // bench::driver; the join synchronises the counts.
                while !stop.load(Ordering::Relaxed) {
                    if batch == 0 {
                        // Unbatched baseline; stop-flag cadence matches
                        // the set driver (every 64 ops).
                        for _ in 0..64 {
                            match cfg.draw_op(&mut rng) {
                                Op::Contains(k) => {
                                    std::hint::black_box(map.get(k));
                                }
                                Op::Add(k) => {
                                    std::hint::black_box(map.insert(k, k));
                                }
                                Op::Remove(k) => {
                                    std::hint::black_box(map.remove(k));
                                }
                            }
                            ops += 1;
                        }
                    } else {
                        ops_buf.clear();
                        for _ in 0..batch {
                            ops_buf.push(map_op(cfg.draw_op(&mut rng)));
                        }
                        map.apply_batch(&ops_buf, &mut replies);
                        std::hint::black_box(replies.last());
                        ops += batch as u64;
                    }
                }
                *slot = (ops, t0.elapsed().as_nanos() as u64);
            }));
        }
        barrier.wait();
        std::thread::sleep(Duration::from_millis(cfg.duration_ms));
        // ORDERING: eventual-visibility stop signal; see the worker
        // loop's load.
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    });

    let (per_thread, per_thread_ns) = slots.into_iter().unzip();
    RunResult::from_workers(per_thread, per_thread_ns)
}

/// Result of one [`run_rmw`] cell.
pub struct RmwResult {
    pub run: RunResult,
    /// Committed increments (every `fetch_add` plus every optimistic
    /// CAS win). The counters must sum to exactly this afterwards —
    /// the atomicity witness `fig16_rmw` asserts per cell.
    pub incs: u64,
    /// Optimistic `compare_exchange` attempts (the read-then-CAS pairs).
    pub cas_attempts: u64,
    /// Attempts that lost the race (the contention signal fig16
    /// reports alongside throughput).
    pub cas_failures: u64,
}

/// Timed conditional-RMW benchmark cell: `threads` workers hammer
/// `keys` hot counters (keys `1..=keys` — small key sets model high
/// contention skew) with the native read-modify-write surface:
/// 70% `fetch_add(k, 1)`, 20% optimistic `get` + `compare_exchange`
/// increments (one attempt, win or lose), 10% `get`. This is the
/// workload the unconditional trio cannot express without locks; the
/// `fig16_rmw` experiment runs it across hot-set size x thread count,
/// K-CAS map vs locked baseline.
pub fn run_rmw(
    map: &dyn ConcurrentMap,
    keys: u64,
    duration_ms: u64,
    threads: usize,
    pin: bool,
    seed: u64,
) -> RmwResult {
    assert!(keys >= 1);
    let stop = AtomicBool::new(false);
    let barrier = Barrier::new(threads + 1);
    let mut slots = vec![(0u64, 0u64); threads];
    let mut stats = vec![(0u64, 0u64, 0u64); threads]; // (incs, attempts, fails)

    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (idx, (slot, stat)) in
            slots.iter_mut().zip(stats.iter_mut()).enumerate()
        {
            let stop = &stop;
            let barrier = &barrier;
            handles.push(s.spawn(move || {
                if pin {
                    affinity::pin_thread(idx);
                }
                let mut rng = Rng::for_thread(seed, idx as u64);
                barrier.wait();
                // Per-worker measurement window, as in
                // `bench::driver::run_prefilled`.
                let t0 = Instant::now();
                let (mut ops, mut incs) = (0u64, 0u64);
                let (mut attempts, mut fails) = (0u64, 0u64);
                // ORDERING: eventual-visibility stop flag, as in
                // bench::driver; the join synchronises the counts.
                while !stop.load(Ordering::Relaxed) {
                    for _ in 0..64 {
                        let k = 1 + rng.below(keys);
                        match rng.below(10) {
                            0 => {
                                std::hint::black_box(map.get(k));
                            }
                            1 | 2 => {
                                // Optimistic read-then-CAS increment:
                                // a single conditional attempt, so the
                                // failure rate exposes the contention.
                                let cur = map.get(k);
                                let next = cur.unwrap_or(0).wrapping_add(1)
                                    & crate::kcas::MAX_VALUE;
                                attempts += 1;
                                if map
                                    .compare_exchange(k, cur, Some(next))
                                    .is_ok()
                                {
                                    incs += 1;
                                } else {
                                    fails += 1;
                                }
                            }
                            _ => {
                                std::hint::black_box(map.fetch_add(k, 1));
                                incs += 1;
                            }
                        }
                        ops += 1;
                    }
                }
                *slot = (ops, t0.elapsed().as_nanos() as u64);
                *stat = (incs, attempts, fails);
            }));
        }
        barrier.wait();
        std::thread::sleep(Duration::from_millis(duration_ms));
        // ORDERING: eventual-visibility stop signal; see the worker
        // loop's load.
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    });

    let (per_thread, per_thread_ns) = slots.into_iter().unzip();
    RmwResult {
        run: RunResult::from_workers(per_thread, per_thread_ns),
        incs: stats.iter().map(|s| s.0).sum(),
        cas_attempts: stats.iter().map(|s| s.1).sum(),
        cas_failures: stats.iter().map(|s| s.2).sum(),
    }
}

/// Which commit engine a `fig18_txn` transfer cell drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TxnEngine {
    /// The map's native [`ConcurrentMap::apply_txn`]: one K-CAS per
    /// commit on the Robin Hood map, 2PL on the locked baseline.
    Native,
    /// The OCC read-validate-write baseline
    /// ([`crate::maps::txn::apply_txn_occ`]), retried on conflict up
    /// to [`OCC_RETRIES`] times per transfer.
    Occ,
}

/// Per-transfer retry budget for the OCC engine before the transfer
/// counts as aborted.
pub const OCC_RETRIES: u32 = 16;

/// Result of one [`run_txn_transfers`] cell.
pub struct TxnTransferResult {
    /// Committed *transactions* (not legs) per worker — so
    /// `run.ops_per_us()` reads as transfers/µs.
    pub run: RunResult,
    pub commits: u64,
    /// Transfers abandoned (OCC retry budget exhausted, or a native
    /// commit reporting an intrinsic conflict). Aborted transfers are
    /// all-or-nothing no-ops, so conservation is unaffected.
    pub aborts: u64,
    /// Conflict retries the OCC engine burned before committing.
    pub retries: u64,
}

/// Timed SmallBank-style transfer cell behind `fig18_txn`: `threads`
/// workers move money between pre-seeded accounts, each transfer one
/// multi-key transaction of `txn_size` legs — one debit of
/// `amt * (txn_size - 1)` plus `txn_size - 1` credits of `amt`, over
/// distinct accounts drawn from `1..=hot` (small `hot` = skewed
/// contention). Every leg is a `FetchAdd` on a pre-seeded key (a pin),
/// so the native engine's commits are intrinsically conflict-free and
/// the cell's grand total is conserved mod 2^62 — the invariant the
/// experiment asserts per cell on the native paths.
pub fn run_txn_transfers(
    map: &dyn ConcurrentMap,
    engine: TxnEngine,
    hot: u64,
    txn_size: usize,
    duration_ms: u64,
    threads: usize,
    pin: bool,
    seed: u64,
) -> TxnTransferResult {
    assert!(txn_size >= 2 && (txn_size as u64) <= hot);
    const M: u64 = 1 << 62; // fetch_add arithmetic is mod 2^62
    let stop = AtomicBool::new(false);
    let barrier = Barrier::new(threads + 1);
    let mut slots = vec![(0u64, 0u64); threads];
    let mut stats = vec![(0u64, 0u64, 0u64); threads]; // (commits, aborts, retries)

    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (idx, (slot, stat)) in
            slots.iter_mut().zip(stats.iter_mut()).enumerate()
        {
            let stop = &stop;
            let barrier = &barrier;
            handles.push(s.spawn(move || {
                if pin {
                    affinity::pin_thread(idx);
                }
                let mut rng = Rng::for_thread(seed, idx as u64);
                let mut ops: Vec<MapOp> = Vec::with_capacity(txn_size);
                let mut accounts: Vec<u64> = Vec::with_capacity(txn_size);
                barrier.wait();
                let t0 = Instant::now();
                let (mut commits, mut aborts, mut retries) = (0u64, 0u64, 0u64);
                // ORDERING: eventual-visibility stop flag, as in
                // bench::driver; the join synchronises the counts.
                while !stop.load(Ordering::Relaxed) {
                    for _ in 0..16 {
                        accounts.clear();
                        while accounts.len() < txn_size {
                            let a = 1 + rng.below(hot);
                            if !accounts.contains(&a) {
                                accounts.push(a);
                            }
                        }
                        let amt = 1 + rng.below(100);
                        ops.clear();
                        ops.push(MapOp::FetchAdd(
                            accounts[0],
                            M - amt * (txn_size as u64 - 1),
                        ));
                        for &a in &accounts[1..] {
                            ops.push(MapOp::FetchAdd(a, amt));
                        }
                        match engine {
                            TxnEngine::Native => match map.apply_txn(&ops) {
                                Ok(_) => commits += 1,
                                Err(_) => aborts += 1,
                            },
                            TxnEngine::Occ => {
                                let mut tries = 0u32;
                                loop {
                                    match crate::maps::txn::apply_txn_occ(
                                        map, &ops,
                                    ) {
                                        Ok(_) => {
                                            commits += 1;
                                            break;
                                        }
                                        Err(_) if tries < OCC_RETRIES => {
                                            tries += 1;
                                            retries += 1;
                                        }
                                        Err(_) => {
                                            aborts += 1;
                                            break;
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
                *slot = (commits, t0.elapsed().as_nanos() as u64);
                *stat = (commits, aborts, retries);
            }));
        }
        barrier.wait();
        std::thread::sleep(Duration::from_millis(duration_ms));
        // ORDERING: eventual-visibility stop signal; see the worker
        // loop's load.
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    });

    let (per_thread, per_thread_ns) = slots.into_iter().unzip();
    TxnTransferResult {
        run: RunResult::from_workers(per_thread, per_thread_ns),
        commits: stats.iter().map(|s| s.0).sum(),
        aborts: stats.iter().map(|s| s.1).sum(),
        retries: stats.iter().map(|s| s.2).sum(),
    }
}

/// Grand total of the transfer accounts after a [`run_txn_transfers`]
/// cell, as a u128 (the per-account balances are 62-bit; summing in
/// u64 could overflow).
pub fn txn_balance_sum(map: &dyn ConcurrentMap, accounts: u64) -> u128 {
    (1..=accounts).map(|k| map.get(k).unwrap_or(0) as u128).sum()
}

/// Sum every hot counter of a finished [`run_rmw`] cell — must equal
/// [`RmwResult::incs`] if (and only if) the map's RMW ops are atomic.
pub fn rmw_counter_sum(map: &dyn ConcurrentMap, keys: u64) -> u64 {
    (1..=keys).map(|k| map.get(k).unwrap_or(0)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::MapKind;

    fn tiny_cfg() -> WorkloadCfg {
        WorkloadCfg::cell(12, 0.4, 10, 50, 3)
    }

    #[test]
    fn apply_batch_returns_in_op_order() {
        let m = MapKind::KCasRhMap.build(8);
        let replies = apply_batch(
            m.as_ref(),
            &[
                MapOp::Insert(1, 10),
                MapOp::Insert(2, 20),
                MapOp::Get(1),
                MapOp::Remove(2),
            ],
        );
        assert_eq!(
            replies,
            vec![
                MapReply::Prev(None),
                MapReply::Prev(None),
                MapReply::Value(Some(10)),
                MapReply::Removed(Some(20)),
            ]
        );
    }

    #[test]
    fn prefill_map_reaches_load_factor() {
        let cfg = tiny_cfg();
        for kind in [
            MapKind::KCasRhMap,
            MapKind::ShardedKCasRhMap { shards: 4 },
        ] {
            let m = kind.build(cfg.size_log2);
            let added = prefill_map(m.as_ref(), &cfg);
            assert_eq!(added, cfg.prefill_count(), "{}", kind.name());
            assert_eq!(m.len_quiesced(), added);
        }
    }

    #[test]
    fn batched_driver_counts_ops() {
        let cfg = tiny_cfg();
        let m = MapKind::ShardedKCasRhMap { shards: 4 }.build(cfg.size_log2);
        prefill_map(m.as_ref(), &cfg);
        for batch in [0usize, 1, 8] {
            let r = run_batched(m.as_ref(), &cfg, 2, batch, false);
            assert_eq!(r.per_thread.len(), 2);
            assert!(r.total_ops > 0, "batch {batch}");
        }
    }

    #[test]
    fn rmw_driver_counters_balance() {
        // The driver's own atomicity witness: committed increments must
        // equal the final counter sum, on both the K-CAS map and the
        // locked baseline.
        for kind in [
            MapKind::ShardedKCasRhMap { shards: 4 },
            MapKind::LockedLpMap,
        ] {
            let m = kind.build(12);
            let r = run_rmw(m.as_ref(), 8, 50, 3, false, 0x16);
            assert!(r.run.total_ops > 0, "{}", kind.name());
            assert_eq!(
                rmw_counter_sum(m.as_ref(), 8),
                r.incs,
                "{}: lost or duplicated increments",
                kind.name()
            );
            assert!(
                r.cas_failures <= r.cas_attempts,
                "{}: {} failures from {} attempts",
                kind.name(),
                r.cas_failures,
                r.cas_attempts
            );
        }
    }

    #[test]
    fn txn_transfer_driver_conserves() {
        // The fig18 cell's own witness: pin-only transfers never abort
        // on the native engines, and the grand total is conserved.
        for (kind, engine) in [
            (MapKind::ShardedKCasRhMap { shards: 4 }, TxnEngine::Native),
            (MapKind::LockedLpMap, TxnEngine::Native),
            (MapKind::ShardedKCasRhMap { shards: 4 }, TxnEngine::Occ),
        ] {
            let m = kind.build(12);
            for k in 1..=64u64 {
                m.insert(k, 1_000);
            }
            let r = run_txn_transfers(
                m.as_ref(),
                engine,
                64,
                3,
                50,
                3,
                false,
                0x18,
            );
            assert!(r.commits > 0, "{} {engine:?}", kind.name());
            if engine == TxnEngine::Native {
                assert_eq!(r.aborts, 0, "{}: native abort", kind.name());
                assert_eq!(
                    txn_balance_sum(m.as_ref(), 64) % (1u128 << 62),
                    64 * 1_000,
                    "{}: money created or destroyed",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn map_op_preserves_keys() {
        assert_eq!(map_op(Op::Contains(5)), MapOp::Get(5));
        assert_eq!(map_op(Op::Add(5)), MapOp::Insert(5, 5));
        assert_eq!(map_op(Op::Remove(5)), MapOp::Remove(5));
    }
}
