//! Epoll event-loop front-end — the readiness-driven sibling of the
//! thread-per-connection pipeline in [`super::server`], speaking the
//! identical wire protocol through the same [`super::frame`] codec.
//!
//! ## Why
//!
//! The threaded front-end spawns two OS threads per connection, so its
//! concurrency ceiling is the scheduler's, not the table's: at a few
//! thousand sockets the machine is context-switching, not hashing.
//! This reactor drives N nonblocking connections per worker thread off
//! `epoll_wait` (raw syscall bindings in [`crate::util::sys`]), which
//! turns socket multiplexing itself into a **batching amplifier**: all
//! ops parsed from every connection that became ready in one wake-up
//! are applied with a *single*
//! [`crate::maps::ConcurrentMap::apply_batch_hashed`] call — one thread-local K-CAS scratch borrow for the whole wave,
//! exactly the amortisation `fig14_batching` measures, but composed
//! from many clients' single-op frames instead of one client's batch
//! frame. The busier the server, the bigger the waves.
//!
//! ## Shape
//!
//! * Accepting has two modes: the default for self-bound servers is
//!   one `SO_REUSEPORT` listener **per worker**
//!   ([`crate::util::sys::bind_reuseport_group`]) — the kernel
//!   load-balances connections and each worker accepts its own, no
//!   hand-off hop; an externally bound listener (which cannot gain
//!   reuseport siblings post-bind) falls back to the legacy accept
//!   thread that deals sockets round-robin into worker inboxes.
//! * Each worker owns an epoll instance, an eventfd inbox wake, and
//!   its connections — no cross-worker sharing, no locks on the hot
//!   path. A wake-up runs three phases: **read** every ready socket
//!   through its [`super::frame::FrameDecoder`], **apply** the
//!   accumulated ops in one hashed batch, **write** replies with
//!   EPOLLOUT-driven flushing.
//! * Backpressure: a connection whose unsent replies exceed
//!   [`HIGH_WATER`] stops being read (its EPOLLIN interest is
//!   dropped) until the backlog drains below [`LOW_WATER`] — a slow
//!   reader throttles itself, not the worker.
//! * Shutdown: [`ReactorHandle::shutdown`] flips the stop flag and
//!   signals every eventfd; accept loop and workers unwind and are
//!   joined, closing every socket.
//!
//! Protocol semantics (`ERR` lines, batch-as-a-unit validation, `Q`,
//! panic containment as `ERR server error` + close) match the
//! threaded backend; `fig17_frontend` asserts all backends' reply
//! transcripts are identical on a fixed trace, and the `map_service`
//! round-trip tier runs against every front-end.

#[cfg(target_os = "linux")]
pub use imp::{
    serve_epoll, serve_epoll_reuseport, spawn_server_epoll, ReactorHandle,
};

#[cfg(not(target_os = "linux"))]
pub use fallback::{serve_epoll, spawn_server_epoll, ReactorHandle};

/// Unsent-reply bytes above which a connection stops being read.
pub const HIGH_WATER: usize = 256 * 1024;
/// Backlog below which a paused connection resumes reading.
pub const LOW_WATER: usize = 64 * 1024;

/// Default worker count (`workers == 0`): one event loop per core,
/// capped — past a handful of loops the table, not the front-end, is
/// the bottleneck.
pub fn default_workers() -> usize {
    crate::util::affinity::available_cpus().clamp(1, 8)
}

#[cfg(target_os = "linux")]
mod imp {
    use std::collections::HashMap;
    use std::io::{self, Read, Write};
    use std::net::{SocketAddr, TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::thread::JoinHandle;

    use super::{default_workers, HIGH_WATER, LOW_WATER};
    use crate::maps::{ConcurrentMap, HashedMapOp, MapOp, MapReply};
    use crate::service::frame::{
        push_reply, txn_err_line, Frame, FrameDecoder, ERR_SERVER,
    };
    use crate::service::panic_message;
    use crate::util::hash::splitmix64;
    use crate::util::metrics::{metrics, stats_line};
    use crate::util::sys::{
        bind_reuseport_group, EpollEvent, EpollFd, EventFd, EPOLLERR,
        EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
    };

    /// Socket-read chunk size; also bounds per-connection bytes pulled
    /// per wake-up (×[`READS_PER_WAKE`]) so one firehose connection
    /// cannot starve its siblings.
    const READ_CHUNK: usize = 16 * 1024;
    const READS_PER_WAKE: usize = 4;
    const MAX_EVENTS: usize = 128;
    /// Epoll token of the worker's inbox eventfd.
    const TOKEN_WAKE: u64 = 0;
    /// Epoll token of the worker's own `SO_REUSEPORT` listener
    /// (multi-listener mode only; connections count up from 2).
    const TOKEN_LISTEN: u64 = 1;

    /// One queued reply action, in frame order (replies must come back
    /// in the order the frames arrived, and `ERR` lines interleave
    /// with batch replies).
    #[derive(Clone, Copy)]
    enum Pending {
        /// Reply line for `batch_ops[start..start + len]` of this wake.
        Ops { start: usize, len: usize },
        /// Reply line for the wake's `idx`-th queued transaction
        /// (`T <n>` frame; committed in phase 2 after the wake batch).
        Txn { idx: usize },
        /// Literal protocol-error line.
        Line(&'static str),
        /// Telemetry snapshot (`STATS`): rendered at reply-format time
        /// so the counters reflect the batch this wake applied.
        Stats,
    }

    /// Phase-2 result of one queued transaction.
    enum TxnOutcome {
        /// Committed: typed replies, one token per op.
        Replies(Vec<MapReply>),
        /// Typed abort (`ERR txn conflict` / `ERR txn unsupported` /
        /// `ERR server error`): one line, nothing applied, connection
        /// stays up.
        Abort(&'static str),
        /// The commit panicked: fatal for the owning connection (same
        /// treatment as a panicked wake batch).
        Panicked,
    }

    struct Conn {
        stream: TcpStream,
        dec: FrameDecoder,
        /// Reply actions accumulated this wake (drained in phase 3).
        pending: Vec<Pending>,
        /// Unsent reply bytes; `sent` is the flushed prefix.
        out: Vec<u8>,
        sent: usize,
        /// Interest set currently registered with epoll.
        interest: u32,
        /// Per-wake flags.
        in_wake: bool,
        readable: bool,
        /// Reading suspended: reply backlog above the high-water mark.
        paused: bool,
        /// No more input will be consumed (Q, EOF-drained, or fatal);
        /// close once the backlog flushes.
        closing: bool,
        /// Socket error: close immediately, no ceremony.
        dead: bool,
        /// Peer finished sending (read returned 0).
        eof: bool,
    }

    impl Conn {
        fn new(stream: TcpStream) -> Conn {
            Conn {
                stream,
                dec: FrameDecoder::new(),
                pending: Vec::new(),
                out: Vec::new(),
                sent: 0,
                interest: EPOLLIN | EPOLLRDHUP,
                in_wake: false,
                readable: false,
                paused: false,
                closing: false,
                dead: false,
                eof: false,
            }
        }

        fn backlog(&self) -> usize {
            self.out.len() - self.sent
        }
    }

    /// Hand-off queue from the accept thread to one worker.
    struct Inbox {
        conns: Mutex<Vec<TcpStream>>,
        wake: EventFd,
    }

    /// Handle to a running epoll server. Dropping it detaches the
    /// server; [`ReactorHandle::shutdown`] stops and joins every
    /// thread (accept + workers), closing all sockets.
    pub struct ReactorHandle {
        addr: SocketAddr,
        stop: Arc<AtomicBool>,
        accept_wake: Arc<EventFd>,
        inboxes: Vec<Arc<Inbox>>,
        threads: Vec<JoinHandle<()>>,
    }

    impl ReactorHandle {
        /// The address the server is listening on.
        pub fn addr(&self) -> SocketAddr {
            self.addr
        }

        /// Stop the accept loop and every worker, join them all, and
        /// close every connection.
        pub fn shutdown(mut self) {
            self.stop.store(true, Ordering::SeqCst);
            self.accept_wake.signal();
            for inbox in &self.inboxes {
                inbox.wake.signal();
            }
            for t in self.threads.drain(..) {
                let _ = t.join();
            }
        }
    }

    /// How fresh connections reach workers.
    enum AcceptMode {
        /// Legacy: one accept thread epolls the shared listener and
        /// deals sockets round-robin into worker inboxes.
        Deal(TcpListener),
        /// One `SO_REUSEPORT` listener per worker: the kernel
        /// load-balances accepts, each worker accepts its own
        /// connections, no hand-off hop.
        PerWorker(Vec<TcpListener>),
    }

    fn serve_on(
        addr: SocketAddr,
        mode: AcceptMode,
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<ReactorHandle> {
        let stop = Arc::new(AtomicBool::new(false));
        let mut inboxes = Vec::with_capacity(workers);
        for _ in 0..workers {
            inboxes.push(Arc::new(Inbox {
                conns: Mutex::new(Vec::new()),
                wake: EventFd::new()?,
            }));
        }
        let accept_wake = Arc::new(EventFd::new()?);
        let (accept, mut per_worker) = match mode {
            AcceptMode::Deal(l) => (Some(l), Vec::new()),
            AcceptMode::PerWorker(ls) => {
                (None, ls.into_iter().map(Some).collect::<Vec<_>>())
            }
        };
        let mut threads = Vec::with_capacity(workers + 1);
        for (i, inbox) in inboxes.iter().enumerate() {
            let (inbox, stop, map) = (inbox.clone(), stop.clone(), map.clone());
            let listener = per_worker.get_mut(i).and_then(Option::take);
            threads.push(std::thread::spawn(move || {
                worker_loop(listener, inbox, stop, map)
            }));
        }
        if let Some(listener) = accept {
            let (inboxes, wake, stop) =
                (inboxes.clone(), accept_wake.clone(), stop.clone());
            threads.push(std::thread::spawn(move || {
                accept_loop(listener, inboxes, wake, stop)
            }));
        }
        Ok(ReactorHandle { addr, stop, accept_wake, inboxes, threads })
    }

    /// Serve `map` on `listener` with `workers` event-loop threads
    /// (0 = [`default_workers`]). `SO_REUSEPORT` must be set pre-bind,
    /// so an externally bound listener cannot gain per-worker
    /// siblings: this path uses the legacy accept-thread deal. Prefer
    /// [`serve_epoll_reuseport`] when the reactor owns the bind.
    pub fn serve_epoll(
        listener: TcpListener,
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<ReactorHandle> {
        let workers = if workers == 0 { default_workers() } else { workers };
        let addr = listener.local_addr()?;
        serve_on(addr, AcceptMode::Deal(listener), map, workers)
    }

    /// Bind `workers` `SO_REUSEPORT` listeners to `addr` (port 0 for
    /// ephemeral) and serve `map` with each worker accepting on its
    /// own — the kernel load-balances connections across workers and
    /// the accept-thread hand-off hop disappears.
    pub fn serve_epoll_reuseport(
        addr: SocketAddr,
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<ReactorHandle> {
        let workers = if workers == 0 { default_workers() } else { workers };
        let (addr, listeners) = bind_reuseport_group(addr, workers)?;
        serve_on(addr, AcceptMode::PerWorker(listeners), map, workers)
    }

    /// Bind an ephemeral localhost port and serve `map` on the epoll
    /// backend (examples, tests, benches). Uses per-worker
    /// `SO_REUSEPORT` listeners, falling back to the legacy
    /// accept-thread deal if the reuseport bind is refused.
    pub fn spawn_server_epoll(
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<ReactorHandle> {
        let local = SocketAddr::from(([127, 0, 0, 1], 0));
        match serve_epoll_reuseport(local, map.clone(), workers) {
            Ok(h) => Ok(h),
            Err(_) => serve_epoll(TcpListener::bind(local)?, map, workers),
        }
    }

    /// Accept thread: epoll on {listener, wake eventfd}; sockets are
    /// dealt round-robin into worker inboxes.
    fn accept_loop(
        listener: TcpListener,
        inboxes: Vec<Arc<Inbox>>,
        wake: Arc<EventFd>,
        stop: Arc<AtomicBool>,
    ) {
        let Ok(ep) = EpollFd::new() else { return };
        if listener.set_nonblocking(true).is_err()
            || ep.add(listener.as_raw_fd(), EPOLLIN, 1).is_err()
            || ep.add(wake.fd(), EPOLLIN, 0).is_err()
        {
            return;
        }
        let mut events = [EpollEvent::zeroed(); 8];
        let mut rr = 0usize;
        loop {
            if ep.wait(&mut events, -1).is_err() {
                return;
            }
            wake.drain();
            if stop.load(Ordering::SeqCst) {
                return; // dropping the listener closes the port
            }
            loop {
                metrics().syscalls_epoll.incr();
                match listener.accept() {
                    Ok((stream, _)) => {
                        let inbox = &inboxes[rr % inboxes.len()];
                        rr += 1;
                        inbox.conns.lock().unwrap().push(stream);
                        inbox.wake.signal();
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                        continue
                    }
                    Err(_) => break,
                }
            }
        }
    }

    /// Multi-listener mode: accept directly on this worker's own
    /// `SO_REUSEPORT` listener — the kernel already picked this
    /// worker, so the socket is registered without any hand-off hop.
    fn accept_direct(
        listener: &TcpListener,
        ep: &EpollFd,
        conns: &mut HashMap<u64, Conn>,
        next_token: &mut u64,
    ) {
        loop {
            metrics().syscalls_epoll.incr();
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    let token = *next_token;
                    *next_token += 1;
                    let conn = Conn::new(stream);
                    if ep
                        .add(conn.stream.as_raw_fd(), conn.interest, token)
                        .is_ok()
                    {
                        conns.insert(token, conn);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    /// Pull freshly accepted sockets out of the inbox and register
    /// them.
    fn adopt_new_conns(
        inbox: &Inbox,
        ep: &EpollFd,
        conns: &mut HashMap<u64, Conn>,
        next_token: &mut u64,
    ) {
        for stream in inbox.conns.lock().unwrap().drain(..) {
            if stream.set_nonblocking(true).is_err() {
                continue;
            }
            stream.set_nodelay(true).ok();
            let token = *next_token;
            *next_token += 1;
            let conn = Conn::new(stream);
            if ep
                .add(conn.stream.as_raw_fd(), conn.interest, token)
                .is_ok()
            {
                conns.insert(token, conn);
            }
        }
    }

    /// Phase 1a: pull bytes off a ready socket into its decoder.
    fn read_some(conn: &mut Conn, chunk: &mut [u8]) {
        for _ in 0..READS_PER_WAKE {
            metrics().syscalls_epoll.incr();
            match (&conn.stream).read(chunk) {
                Ok(0) => {
                    conn.eof = true;
                    return;
                }
                Ok(n) => {
                    metrics().bytes_in_epoll.add(n as u64);
                    conn.dec.feed(&chunk[..n]);
                    if n < chunk.len() {
                        return; // likely drained; level-trigger re-arms
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    conn.dead = true;
                    return;
                }
            }
        }
    }

    /// Phase 1b: decode complete frames, accumulating batch ops (with
    /// their routing hash) into the wake-wide batch and recording the
    /// per-connection reply actions in frame order. A `T <n>` frame
    /// ends the connection's parsing for this wake: the wake batch is
    /// applied *before* queued transactions, so frames decoded after a
    /// transaction must wait for the next wake (the replay set) to
    /// observe its commit — per-connection program order is what the
    /// cross-backend equivalence trace asserts.
    fn parse_frames(
        conn: &mut Conn,
        batch_ops: &mut Vec<HashedMapOp>,
        txns: &mut Vec<Vec<MapOp>>,
    ) {
        while !conn.closing && conn.backlog() <= HIGH_WATER {
            let frame = match conn.dec.next_frame() {
                Some(f) => f,
                // At EOF a final line without a trailing newline still
                // deserves its reply (matches the threaded backend).
                None if conn.eof => match conn.dec.finish() {
                    Some(f) => f,
                    None => break,
                },
                None => break,
            };
            match frame {
                Frame::Batch(ops) => {
                    let start = batch_ops.len();
                    batch_ops.extend(
                        ops.iter().map(|&op| (splitmix64(op.key()), op)),
                    );
                    conn.pending.push(Pending::Ops { start, len: ops.len() });
                }
                Frame::Txn(ops) => {
                    conn.pending.push(Pending::Txn { idx: txns.len() });
                    txns.push(ops);
                    break;
                }
                Frame::Err(e) => conn.pending.push(Pending::Line(e)),
                Frame::Stats => conn.pending.push(Pending::Stats),
                Frame::Quit => {
                    // Like the threaded backend: no reply to Q, stop
                    // consuming input, close once replies flush.
                    conn.closing = true;
                }
            }
        }
    }

    /// Phase 3a: render this connection's reply lines into its output
    /// buffer. If the wake batch panicked (e.g. the table's "map is
    /// full" capacity assert), the batch may have applied partially
    /// and cannot be retried — re-applying would double-apply
    /// non-idempotent ops like fetch-add — so every connection with
    /// ops in the doomed batch gets the threaded backend's fatal
    /// treatment: one `ERR server error` line, then close. `ERR`
    /// lines queued before the failing frame still go out in order.
    fn format_replies(
        conn: &mut Conn,
        replies: &[MapReply],
        txn_results: &[TxnOutcome],
        panicked: bool,
        line: &mut String,
    ) {
        // Index loop (not drain/take) so the pending buffer keeps its
        // capacity — this runs per connection per wake on the hot path.
        for i in 0..conn.pending.len() {
            line.clear();
            match conn.pending[i] {
                Pending::Line(e) => line.push_str(e),
                Pending::Stats => line.push_str(&stats_line()),
                Pending::Ops { start, len } => {
                    if panicked {
                        // Fatal: error line, discard the rest of this
                        // connection's pendings, close after flush.
                        conn.out.extend_from_slice(ERR_SERVER.as_bytes());
                        conn.out.push(b'\n');
                        conn.closing = true;
                        break;
                    }
                    for (j, &r) in
                        replies[start..start + len].iter().enumerate()
                    {
                        if j > 0 {
                            line.push(' ');
                        }
                        push_reply(r, line);
                    }
                }
                Pending::Txn { idx } => match &txn_results[idx] {
                    TxnOutcome::Replies(rs) => {
                        for (j, &r) in rs.iter().enumerate() {
                            if j > 0 {
                                line.push(' ');
                            }
                            push_reply(r, line);
                        }
                    }
                    TxnOutcome::Abort(e) => line.push_str(e),
                    TxnOutcome::Panicked => {
                        conn.out.extend_from_slice(ERR_SERVER.as_bytes());
                        conn.out.push(b'\n');
                        conn.closing = true;
                        break;
                    }
                },
            }
            line.push('\n');
            conn.out.extend_from_slice(line.as_bytes());
        }
        conn.pending.clear();
    }

    /// Phase 3b: push buffered replies to the socket.
    fn try_flush(conn: &mut Conn) {
        while conn.sent < conn.out.len() {
            metrics().syscalls_epoll.incr();
            match (&conn.stream).write(&conn.out[conn.sent..]) {
                Ok(0) => {
                    conn.dead = true;
                    break;
                }
                Ok(n) => {
                    metrics().bytes_out_epoll.add(n as u64);
                    conn.sent += n;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    conn.dead = true;
                    break;
                }
            }
        }
        if conn.sent == conn.out.len() {
            conn.out.clear();
            conn.sent = 0;
        } else if conn.sent > LOW_WATER {
            // Compact so the buffer tracks the backlog, not history.
            conn.out.drain(..conn.sent);
            conn.sent = 0;
        }
    }

    fn worker_loop(
        listener: Option<TcpListener>,
        inbox: Arc<Inbox>,
        stop: Arc<AtomicBool>,
        map: Arc<dyn ConcurrentMap>,
    ) {
        let Ok(ep) = EpollFd::new() else { return };
        if ep.add(inbox.wake.fd(), EPOLLIN, TOKEN_WAKE).is_err() {
            return;
        }
        if let Some(l) = &listener {
            if l.set_nonblocking(true).is_err()
                || ep.add(l.as_raw_fd(), EPOLLIN, TOKEN_LISTEN).is_err()
            {
                return;
            }
        }
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut next_token: u64 = 2;
        let mut events = vec![EpollEvent::zeroed(); MAX_EVENTS];
        let mut chunk = vec![0u8; READ_CHUNK];
        let mut batch_ops: Vec<HashedMapOp> = Vec::new();
        let mut txns: Vec<Vec<MapOp>> = Vec::new();
        let mut txn_results: Vec<TxnOutcome> = Vec::new();
        let mut replies: Vec<MapReply> = Vec::new();
        let mut line = String::new();
        let mut touched: Vec<u64> = Vec::new();
        let mut replay: Vec<u64> = Vec::new();
        let mut to_close: Vec<u64> = Vec::new();

        'outer: loop {
            // A nonzero replay set means unpaused connections still
            // hold decoded-but-unanswered frames: poll, don't sleep.
            let timeout = if replay.is_empty() { -1 } else { 0 };
            let n = match ep.wait(&mut events, timeout) {
                Ok(n) => n,
                Err(_) => return,
            };
            touched.clear();
            batch_ops.clear();
            txns.clear();
            txn_results.clear();

            // Re-admit replayed connections first (frame order within
            // a connection is preserved: its decoder is the queue).
            for token in replay.drain(..) {
                if let Some(conn) = conns.get_mut(&token) {
                    conn.in_wake = true;
                    conn.readable = true;
                    touched.push(token);
                }
            }
            for i in 0..n {
                let (ev, token) = (events[i].events, events[i].data);
                if token == TOKEN_WAKE {
                    inbox.wake.drain();
                    if stop.load(Ordering::SeqCst) {
                        break 'outer;
                    }
                    adopt_new_conns(&inbox, &ep, &mut conns, &mut next_token);
                    continue;
                }
                if token == TOKEN_LISTEN {
                    if let Some(l) = &listener {
                        accept_direct(l, &ep, &mut conns, &mut next_token);
                    }
                    continue;
                }
                let Some(conn) = conns.get_mut(&token) else { continue };
                if !conn.in_wake {
                    conn.in_wake = true;
                    touched.push(token);
                }
                if ev & EPOLLERR != 0 {
                    conn.dead = true;
                }
                if ev & (EPOLLIN | EPOLLRDHUP | EPOLLHUP) != 0 {
                    conn.readable = true;
                }
                // EPOLLOUT needs no flag: every touched connection
                // gets a flush attempt in phase 3.
            }

            // Phase 1: read ready sockets, decode frames, accumulate
            // the wake-wide hashed op batch.
            for &token in &touched {
                let conn = conns.get_mut(&token).expect("touched conn");
                if conn.readable && !conn.paused && !conn.closing && !conn.dead
                {
                    if !conn.eof {
                        read_some(conn, &mut chunk);
                    }
                    parse_frames(conn, &mut batch_ops, &mut txns);
                }
            }

            // Phase 2: one table call for every op this wake delivered,
            // across all connections — the multiplexer *is* the batch.
            let mut panicked = false;
            if !batch_ops.is_empty() {
                let applied = catch_unwind(AssertUnwindSafe(|| {
                    map.apply_batch_hashed(&batch_ops, &mut replies)
                }));
                if let Err(payload) = applied {
                    panicked = true;
                    metrics().server_panics.incr();
                    eprintln!(
                        "crh-reactor: contained panic in wake batch \
                         ({} ops across {} conns): {}",
                        batch_ops.len(),
                        touched.len(),
                        panic_message(payload.as_ref()),
                    );
                }
            }
            // Queued transactions commit after the wake batch (each
            // connection stopped parsing at its first txn frame, so
            // per-connection frame order holds either way).
            for ops in &txns {
                let applied =
                    catch_unwind(AssertUnwindSafe(|| map.apply_txn(ops)));
                txn_results.push(match applied {
                    Ok(Ok(rs)) => TxnOutcome::Replies(rs),
                    Ok(Err(e)) => TxnOutcome::Abort(txn_err_line(&e)),
                    Err(payload) => {
                        metrics().server_panics.incr();
                        eprintln!(
                            "crh-reactor: contained panic in txn \
                             ({} ops): {}",
                            ops.len(),
                            panic_message(payload.as_ref()),
                        );
                        TxnOutcome::Panicked
                    }
                });
            }

            // Phase 3: format replies, flush, manage interest sets.
            for &token in &touched {
                let conn = conns.get_mut(&token).expect("touched conn");
                conn.in_wake = false;
                conn.readable = false;
                if conn.dead {
                    to_close.push(token);
                    continue;
                }
                format_replies(
                    conn,
                    &replies,
                    &txn_results,
                    panicked,
                    &mut line,
                );
                try_flush(conn);
                if conn.dead {
                    to_close.push(token);
                    continue;
                }
                // Backpressure transitions.
                if !conn.paused && conn.backlog() > HIGH_WATER {
                    conn.paused = true;
                    metrics().backpressure_pauses.incr();
                } else if conn.paused && conn.backlog() <= LOW_WATER {
                    conn.paused = false;
                    metrics().backpressure_resumes.incr();
                }
                // Withheld frames — backpressure unpause, or parsing
                // stopped at a transaction boundary to preserve
                // per-connection frame order: serve them next wake.
                if !conn.paused
                    && !conn.closing
                    && (conn.dec.has_complete_line()
                        || (conn.eof && conn.dec.buffered() > 0))
                {
                    replay.push(token);
                }
                // EOF: once the decoder is fully drained (parse_frames
                // ran finish() for any unterminated final line), the
                // connection is done — close after the flush.
                if conn.eof && !conn.paused && conn.dec.buffered() == 0 {
                    conn.closing = true;
                }
                if conn.closing && conn.backlog() == 0 {
                    to_close.push(token);
                    continue;
                }
                let mut want = 0u32;
                if !conn.closing && !conn.paused && !conn.eof {
                    want |= EPOLLIN | EPOLLRDHUP;
                }
                if conn.backlog() > 0 {
                    want |= EPOLLOUT;
                }
                if want != conn.interest {
                    if ep
                        .modify(conn.stream.as_raw_fd(), want, token)
                        .is_err()
                    {
                        to_close.push(token);
                        continue;
                    }
                    conn.interest = want;
                }
            }
            for token in to_close.drain(..) {
                // Dropping the stream closes the fd, which also
                // removes it from the epoll set.
                conns.remove(&token);
            }
        }
        // Shutdown: drop all connections (sockets close with them).
    }
}

#[cfg(not(target_os = "linux"))]
mod fallback {
    //! Epoll is Linux-only; elsewhere the "reactor" API serves through
    //! the thread-per-connection backend so callers (benches, tests,
    //! the CLI) stay portable. The protocol is identical either way.

    use std::io;
    use std::net::{SocketAddr, TcpListener};
    use std::sync::Arc;

    use crate::maps::ConcurrentMap;
    use crate::service::server::{self, ServerHandle};

    pub struct ReactorHandle(ServerHandle);

    impl ReactorHandle {
        pub fn addr(&self) -> SocketAddr {
            self.0.addr()
        }

        pub fn shutdown(self) {
            self.0.shutdown()
        }
    }

    pub fn serve_epoll(
        listener: TcpListener,
        map: Arc<dyn ConcurrentMap>,
        _workers: usize,
    ) -> io::Result<ReactorHandle> {
        server::spawn_server_on(listener, map).map(ReactorHandle)
    }

    pub fn spawn_server_epoll(
        map: Arc<dyn ConcurrentMap>,
        _workers: usize,
    ) -> io::Result<ReactorHandle> {
        serve_epoll(TcpListener::bind("127.0.0.1:0")?, map, _workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{ConcurrentMap, MapKind, MapOp};
    use crate::service::server::Client;
    use std::sync::Arc;

    fn map() -> Arc<dyn ConcurrentMap> {
        Arc::from(MapKind::ShardedKCasRhMap { shards: 4 }.build(12))
    }

    #[test]
    #[cfg_attr(miri, ignore = "real epoll and TCP; no kernel under Miri")]
    fn round_trip_and_shutdown_joins() {
        let h = spawn_server_epoll(map(), 2).unwrap();
        let mut c = Client::connect(h.addr()).unwrap();
        assert_eq!(c.request_line("P 5 50").unwrap(), "-");
        assert_eq!(c.request_line("G 5").unwrap(), "50");
        assert_eq!(c.request_line("A 5 1").unwrap(), "50");
        assert_eq!(c.request_line("C 5 51 -").unwrap(), "OK");
        assert_eq!(c.request_line("G 0").unwrap(), "ERR key out of range");
        let replies = c
            .batch(&[MapOp::Insert(7, 70), MapOp::Get(7), MapOp::Remove(7)])
            .unwrap();
        assert_eq!(replies, vec![None, Some(70), Some(70)]);
        // The property under test: shutdown *returns* — accept loop
        // and workers joined, no stranded threads.
        h.shutdown();
    }

    #[test]
    #[cfg_attr(miri, ignore = "real epoll and TCP; no kernel under Miri")]
    fn quit_closes_after_replies_flush() {
        let h = spawn_server_epoll(map(), 1).unwrap();
        let mut c = Client::connect(h.addr()).unwrap();
        // One write carrying work *and* the quit: both replies must
        // still arrive before the close.
        c.send_raw(b"P 9 90\nG 9\nQ\n").unwrap();
        assert_eq!(c.read_reply_line().unwrap(), "-");
        assert_eq!(c.read_reply_line().unwrap(), "90");
        assert!(c.read_reply_line().is_err(), "connection should be closed");
        h.shutdown();
    }

    #[test]
    #[cfg_attr(miri, ignore = "real epoll and TCP; no kernel under Miri")]
    fn many_connections_share_workers() {
        let m = map();
        let h = spawn_server_epoll(m.clone(), 2).unwrap();
        let addr = h.addr();
        let mut handles = Vec::new();
        for tid in 0..16u64 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let base = 1 + tid * 1000;
                for k in base..base + 50 {
                    assert_eq!(
                        c.request_line(&format!("P {k} {k}")).unwrap(),
                        "-"
                    );
                }
                let ops: Vec<MapOp> =
                    (base..base + 50).map(MapOp::Get).collect();
                let got = c.batch(&ops).unwrap();
                assert!(got
                    .iter()
                    .zip(base..base + 50)
                    .all(|(v, k)| *v == Some(k)));
            }));
        }
        for th in handles {
            th.join().unwrap();
        }
        assert_eq!(m.len_quiesced(), 16 * 50);
        h.shutdown();
    }
}
