//! A lightweight Rust lexer for the in-tree lint pass.
//!
//! This is not a parser: it produces a flat token stream with source
//! spans, which is exactly enough for the adjacency- and
//! pattern-matching rules in [`super::rules`]. What it *must* get
//! right — because every rule depends on it — is classification:
//! comments (line, block with nesting, doc), string-ish literals
//! (plain, raw with `#` fences, byte, byte-raw), char literals vs
//! lifetimes, and raw identifiers. A rule that mistook the word
//! `unsafe` inside a doc comment or a string for the keyword would
//! drown the real findings in noise.
//!
//! Numbers and multi-character punctuation are deliberately sloppy
//! (`1e-5` lexes as three tokens, `::` as two colons): no rule needs
//! them, and keeping the lexer small keeps it auditable.

/// Token classification. Comments are *kept* in the stream — the
/// rules' whole job is reasoning about comment adjacency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (including raw `r#ident`).
    Ident,
    /// `'lifetime` (no closing quote).
    Lifetime,
    /// Char or byte literal: `'x'`, `'\n'`, `b'x'`.
    Char,
    /// String-ish literal: `"…"`, `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`.
    Str,
    /// Numeric literal.
    Num,
    /// Single punctuation character.
    Punct,
    /// `// …` (includes `///` and `//!` doc comments).
    LineComment,
    /// `/* … */`, nesting handled; may span lines.
    BlockComment,
}

/// One token with its source span (1-based line/column of the first
/// character; `end_line` for multi-line block comments and strings).
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
    pub end_line: u32,
}

impl Tok {
    /// Does this (comment) token's text carry `marker`?
    pub fn contains(&self, marker: &str) -> bool {
        self.text.contains(marker)
    }

    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.chars().next() == Some(c)
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Cursor over the source chars with line/column tracking.
struct Cursor {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Cursor {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
}

/// Lex `src` into a token stream. Whitespace is dropped; everything
/// else (comments included) becomes a token. Unterminated literals and
/// comments lex as one token running to end-of-file — the lint then
/// still sees every site before the breakage, and rustc itself is the
/// authority on rejecting such a file.
pub fn lex(src: &str) -> Vec<Tok> {
    let mut cur = Cursor { chars: src.chars().collect(), i: 0, line: 1, col: 1 };
    let mut out = Vec::new();
    while let Some(c) = cur.peek(0) {
        let (line, col) = (cur.line, cur.col);
        match c {
            _ if c.is_whitespace() => {
                cur.bump();
            }
            '/' if cur.peek(1) == Some('/') => {
                let mut text = String::new();
                while let Some(c) = cur.peek(0) {
                    if c == '\n' {
                        break;
                    }
                    text.push(c);
                    cur.bump();
                }
                out.push(Tok {
                    kind: TokKind::LineComment,
                    text,
                    line,
                    col,
                    end_line: line,
                });
            }
            '/' if cur.peek(1) == Some('*') => {
                let mut text = String::new();
                let mut depth = 0usize;
                while let Some(c) = cur.peek(0) {
                    if c == '/' && cur.peek(1) == Some('*') {
                        depth += 1;
                        text.push_str("/*");
                        cur.bump();
                        cur.bump();
                    } else if c == '*' && cur.peek(1) == Some('/') {
                        depth -= 1;
                        text.push_str("*/");
                        cur.bump();
                        cur.bump();
                        if depth == 0 {
                            break;
                        }
                    } else {
                        text.push(c);
                        cur.bump();
                    }
                }
                out.push(Tok {
                    kind: TokKind::BlockComment,
                    text,
                    line,
                    col,
                    end_line: cur.line,
                });
            }
            // Raw strings and raw identifiers share the `r` prefix;
            // byte strings add a `b`. Decide by lookahead before
            // falling back to a plain identifier.
            'r' | 'b' if starts_string_like(&cur) => {
                out.push(lex_string_like(&mut cur, line, col));
            }
            '\'' => out.push(lex_quote(&mut cur, line, col)),
            '"' => out.push(lex_plain_string(&mut cur, line, col, '"')),
            _ if is_ident_start(c) => {
                let mut text = String::new();
                // Raw identifier: keep the `r#` prefix in the token
                // text, so `r#unsafe` is NOT the keyword `unsafe`.
                if c == 'r'
                    && cur.peek(1) == Some('#')
                    && cur.peek(2).is_some_and(is_ident_start)
                {
                    text.push_str("r#");
                    cur.bump();
                    cur.bump();
                }
                while let Some(c) = cur.peek(0) {
                    if !is_ident_continue(c) {
                        break;
                    }
                    text.push(c);
                    cur.bump();
                }
                out.push(Tok {
                    kind: TokKind::Ident,
                    text,
                    line,
                    col,
                    end_line: line,
                });
            }
            _ if c.is_ascii_digit() => {
                let mut text = String::new();
                while let Some(c) = cur.peek(0) {
                    if is_ident_continue(c) {
                        text.push(c);
                        cur.bump();
                    } else if c == '.'
                        && cur.peek(1).is_some_and(|d| d.is_ascii_digit())
                        && !text.contains('.')
                    {
                        text.push(c);
                        cur.bump();
                    } else {
                        break;
                    }
                }
                out.push(Tok {
                    kind: TokKind::Num,
                    text,
                    line,
                    col,
                    end_line: line,
                });
            }
            _ => {
                cur.bump();
                out.push(Tok {
                    kind: TokKind::Punct,
                    text: c.to_string(),
                    line,
                    col,
                    end_line: line,
                });
            }
        }
    }
    out
}

/// Is the cursor (sitting on `r` or `b`) at the start of a raw / byte
/// string or byte char, as opposed to an ordinary identifier?
fn starts_string_like(cur: &Cursor) -> bool {
    match (cur.peek(0), cur.peek(1)) {
        (Some('r'), Some('"')) => true,
        (Some('r'), Some('#')) => {
            // r#"…"# is a raw string; r#ident is a raw identifier.
            let mut j = 1;
            while cur.peek(j) == Some('#') {
                j += 1;
            }
            cur.peek(j) == Some('"')
        }
        (Some('b'), Some('"')) | (Some('b'), Some('\'')) => true,
        (Some('b'), Some('r')) => {
            matches!(cur.peek(2), Some('"') | Some('#'))
        }
        _ => false,
    }
}

/// Lex `r"…"`, `r#+"…"#+`, `b"…"`, `br…`, `b'…'` (cursor on the
/// prefix letter).
fn lex_string_like(cur: &mut Cursor, line: u32, col: u32) -> Tok {
    let mut text = String::new();
    let mut raw = false;
    // Consume the prefix letters (`r`, `b`, `br`).
    while let Some(c) = cur.peek(0) {
        if c == 'r' || c == 'b' {
            if c == 'r' {
                raw = true;
            }
            text.push(c);
            cur.bump();
        } else {
            break;
        }
    }
    if cur.peek(0) == Some('\'') {
        // b'x' — byte char.
        let t = lex_quote(cur, line, col);
        return Tok { text: text + &t.text, ..t };
    }
    if raw {
        let mut hashes = 0usize;
        while cur.peek(0) == Some('#') {
            hashes += 1;
            text.push('#');
            cur.bump();
        }
        text.push('"');
        cur.bump(); // opening quote
        let mut fence = String::from("\"");
        for _ in 0..hashes {
            fence.push('#');
        }
        loop {
            match cur.peek(0) {
                None => break,
                Some('"') => {
                    // Candidate close: must be followed by `hashes` #s.
                    let matched =
                        (1..=hashes).all(|k| cur.peek(k) == Some('#'));
                    if matched {
                        text.push_str(&fence);
                        for _ in 0..=hashes {
                            cur.bump();
                        }
                        break;
                    }
                    text.push('"');
                    cur.bump();
                }
                Some(c) => {
                    text.push(c);
                    cur.bump();
                }
            }
        }
        Tok { kind: TokKind::Str, text, line, col, end_line: cur.line }
    } else {
        let t = lex_plain_string(cur, line, col, '"');
        Tok { text: text + &t.text, ..t }
    }
}

/// Lex a `"…"` string with escapes (cursor on the opening quote).
fn lex_plain_string(cur: &mut Cursor, line: u32, col: u32, quote: char) -> Tok {
    let mut text = String::new();
    text.push(quote);
    cur.bump();
    while let Some(c) = cur.peek(0) {
        if c == '\\' {
            text.push(c);
            cur.bump();
            if let Some(e) = cur.peek(0) {
                text.push(e);
                cur.bump();
            }
        } else if c == quote {
            text.push(c);
            cur.bump();
            break;
        } else {
            text.push(c);
            cur.bump();
        }
    }
    Tok { kind: TokKind::Str, text, line, col, end_line: cur.line }
}

/// Lex from a `'`: either a char literal (`'x'`, `'\n'`) or a
/// lifetime (`'a`, `'static`). The grammar is ambiguous one character
/// at a time, so look ahead: an escape or a close-quote two chars out
/// means char literal, an identifier run without a closing quote means
/// lifetime.
fn lex_quote(cur: &mut Cursor, line: u32, col: u32) -> Tok {
    debug_assert_eq!(cur.peek(0), Some('\''));
    let next = cur.peek(1);
    let is_char = match next {
        Some('\\') => true,
        Some(c) if is_ident_start(c) => {
            // 'a' vs 'a: scan the ident run; a closing quote right
            // after it makes this a char literal.
            let mut j = 2;
            while cur.peek(j).is_some_and(is_ident_continue) {
                j += 1;
            }
            cur.peek(j) == Some('\'')
        }
        // '1', ' ', '(' … anything non-ident with a close quote after.
        Some(_) => cur.peek(2) == Some('\''),
        None => false,
    };
    let mut text = String::new();
    text.push('\'');
    cur.bump();
    if is_char {
        while let Some(c) = cur.peek(0) {
            if c == '\\' {
                text.push(c);
                cur.bump();
                if let Some(e) = cur.peek(0) {
                    text.push(e);
                    cur.bump();
                }
            } else if c == '\'' {
                text.push(c);
                cur.bump();
                break;
            } else {
                text.push(c);
                cur.bump();
            }
        }
        Tok { kind: TokKind::Char, text, line, col, end_line: cur.line }
    } else {
        while let Some(c) = cur.peek(0) {
            if !is_ident_continue(c) {
                break;
            }
            text.push(c);
            cur.bump();
        }
        Tok { kind: TokKind::Lifetime, text, line, col, end_line: line }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_keywords_punct() {
        let toks = kinds("unsafe { x.y() }");
        assert_eq!(
            toks,
            vec![
                (TokKind::Ident, "unsafe".into()),
                (TokKind::Punct, "{".into()),
                (TokKind::Ident, "x".into()),
                (TokKind::Punct, ".".into()),
                (TokKind::Ident, "y".into()),
                (TokKind::Punct, "(".into()),
                (TokKind::Punct, ")".into()),
                (TokKind::Punct, "}".into()),
            ]
        );
    }

    #[test]
    fn line_and_doc_comments_keep_text() {
        let toks = lex("// SAFETY: fine\n/// docs\nlet x = 1;");
        assert_eq!(toks[0].kind, TokKind::LineComment);
        assert!(toks[0].contains("SAFETY:"));
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].kind, TokKind::LineComment);
        assert_eq!(toks[1].text, "/// docs");
        assert_eq!(toks[2].text, "let");
        assert_eq!(toks[2].line, 3);
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("/* a /* nested */ b */ x /* tail");
        assert_eq!(toks[0].kind, TokKind::BlockComment);
        assert_eq!(toks[0].text, "/* a /* nested */ b */");
        assert_eq!(toks[1].text, "x");
        // Unterminated tail comment runs to EOF as one token.
        assert_eq!(toks[2].kind, TokKind::BlockComment);
        assert_eq!(toks.len(), 3);
    }

    #[test]
    fn block_comment_spans_lines() {
        let toks = lex("/* one\ntwo\nthree */ unsafe");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[0].end_line, 3);
        assert_eq!(toks[1].text, "unsafe");
        assert_eq!(toks[1].line, 3);
    }

    #[test]
    fn strings_hide_their_contents() {
        // The word `unsafe` inside any string form must not become an
        // Ident token.
        for src in [
            "\"unsafe { }\"",
            "r\"unsafe\"",
            "r#\"unsafe \" still\"#",
            "r##\"one \"# two\"##",
            "b\"unsafe\"",
            "br#\"unsafe\"#",
        ] {
            let toks = lex(src);
            assert_eq!(toks.len(), 1, "src {src:?} -> {toks:?}");
            assert_eq!(toks[0].kind, TokKind::Str, "src {src:?}");
        }
    }

    #[test]
    fn string_escapes_do_not_end_early() {
        let toks = kinds(r#""a \" b" x"#);
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[0].0, TokKind::Str);
        assert_eq!(toks[1].1, "x");
    }

    #[test]
    fn raw_ident_is_not_the_keyword() {
        let toks = kinds("r#unsafe x");
        assert_eq!(toks[0], (TokKind::Ident, "r#unsafe".into()));
        assert_eq!(toks[1], (TokKind::Ident, "x".into()));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds("&'a T; 'x'; '\\n'; '\\''; b'z'; 'static");
        let find = |txt: &str| {
            toks.iter().find(|(_, t)| t == txt).map(|(k, _)| *k)
        };
        assert_eq!(find("'a"), Some(TokKind::Lifetime));
        assert_eq!(find("'x'"), Some(TokKind::Char));
        assert_eq!(find("'\\n'"), Some(TokKind::Char));
        assert_eq!(find("'\\''"), Some(TokKind::Char));
        assert_eq!(find("b'z'"), Some(TokKind::Char));
        assert_eq!(find("'static"), Some(TokKind::Lifetime));
    }

    #[test]
    fn char_literal_with_digit_and_space() {
        assert_eq!(kinds("'1'")[0].0, TokKind::Char);
        assert_eq!(kinds("' '")[0].0, TokKind::Char);
        assert_eq!(kinds("'{'")[0].0, TokKind::Char);
    }

    #[test]
    fn numbers_lex_whole() {
        let toks = kinds("0x1f 1_000 0.5 1..9");
        assert_eq!(toks[0], (TokKind::Num, "0x1f".into()));
        assert_eq!(toks[1], (TokKind::Num, "1_000".into()));
        assert_eq!(toks[2], (TokKind::Num, "0.5".into()));
        // Range: the dots stay punct, both endpoints are numbers.
        assert_eq!(toks[3], (TokKind::Num, "1".into()));
        assert_eq!(toks[4], (TokKind::Punct, ".".into()));
        assert_eq!(toks[5], (TokKind::Punct, ".".into()));
        assert_eq!(toks[6], (TokKind::Num, "9".into()));
    }

    #[test]
    fn spans_are_one_based_and_accurate() {
        let toks = lex("ab cd\n  ef");
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (1, 4));
        assert_eq!((toks[2].line, toks[2].col), (2, 3));
    }
}
