//! The lint rules (`L001`–`L005`) over the [`super::lexer`] token
//! stream.
//!
//! | id   | rule |
//! |------|------|
//! | L001 | every `unsafe` (block, fn, impl, trait) needs an adjacent `// SAFETY:` comment (or a `# Safety` doc section) |
//! | L002 | every `Ordering::Relaxed` outside `util::metrics` / test code needs an adjacent `// ORDERING:` comment |
//! | L003 | every `#[allow(…)]` / `#![allow(…)]` needs an adjacent justification comment |
//! | L004 | metric name strings: declared exactly once in the `util::metrics` `REGISTRY`, and every `.counter("…")` / `.hist("…")` lookup names a declared metric |
//! | L005 | every `Frame` variant the `service::frame` codec can yield is dispatched in all three backends (`server.rs`, `reactor.rs`, `uring.rs`) |
//!
//! "Adjacent" means: a comment on the same line as the site, in the
//! contiguous comment/attribute block directly above it (blank lines
//! break adjacency), mid-statement between the statement start and the
//! site, or in the comment block directly above the start of the
//! statement containing the site. That covers every reasonable comment
//! placement while rejecting a justification stranded behind
//! unrelated code.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::path::{Path, PathBuf};

use super::lexer::{lex, Tok, TokKind};

/// One span-accurate diagnostic. `line`/`col` are 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diag {
    pub rule: &'static str,
    pub path: PathBuf,
    pub line: u32,
    pub col: u32,
    pub msg: String,
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}: {} {}",
            self.path.display(),
            self.line,
            self.col,
            self.rule,
            self.msg
        )
    }
}

/// One attribute occurrence: `#[name(…)]` or `#![name(…)]`.
struct Attr {
    /// Index of the opening `#` token.
    hash_idx: usize,
    /// Index one past the closing `]`.
    end_idx: usize,
    /// First identifier inside the brackets (`allow`, `cfg`, `test` …).
    name: String,
    /// All identifiers inside the brackets, in order.
    inner: Vec<String>,
}

/// A lexed file plus the derived indexes the rules share.
pub struct SourceFile {
    pub path: PathBuf,
    toks: Vec<Tok>,
    attrs: Vec<Attr>,
    /// Token is part of an attribute (`#`, brackets and contents).
    attr_tok: Vec<bool>,
    /// Token sits inside a `#[cfg(test)]` / `#[test]` item body.
    test_tok: Vec<bool>,
    /// Lines carrying at least one non-comment, non-attribute token.
    code_lines: HashSet<u32>,
    /// Lines whose only non-comment tokens belong to attributes.
    attr_lines: HashSet<u32>,
    /// Line -> indexes of comment tokens covering that line.
    comments_by_line: HashMap<u32, Vec<usize>>,
}

impl SourceFile {
    pub fn new(path: PathBuf, src: &str) -> SourceFile {
        let toks = lex(src);
        let attrs = collect_attrs(&toks);
        let mut attr_tok = vec![false; toks.len()];
        for a in &attrs {
            for t in attr_tok.iter_mut().take(a.end_idx).skip(a.hash_idx) {
                *t = true;
            }
        }
        let test_tok = mark_test_regions(&toks, &attrs);

        let mut code_lines = HashSet::new();
        let mut attr_line_cand = HashSet::new();
        let mut comments_by_line: HashMap<u32, Vec<usize>> = HashMap::new();
        for (i, t) in toks.iter().enumerate() {
            if t.is_comment() {
                for l in t.line..=t.end_line {
                    comments_by_line.entry(l).or_default().push(i);
                }
            } else if attr_tok[i] {
                for l in t.line..=t.end_line {
                    attr_line_cand.insert(l);
                }
            } else {
                for l in t.line..=t.end_line {
                    code_lines.insert(l);
                }
            }
        }
        let attr_lines =
            attr_line_cand.difference(&code_lines).copied().collect();
        SourceFile {
            path,
            toks,
            attrs,
            attr_tok,
            test_tok,
            code_lines,
            attr_lines,
            comments_by_line,
        }
    }

    /// Do the path's trailing components match `suffix` (e.g.
    /// `["util", "metrics.rs"]`)?
    fn path_ends_with(&self, suffix: &[&str]) -> bool {
        let comps: Vec<_> = self
            .path
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect();
        comps.len() >= suffix.len()
            && comps[comps.len() - suffix.len()..]
                .iter()
                .zip(suffix)
                .all(|(a, b)| a == b)
    }

    /// Is this file test code by location (an integration-test tree)?
    fn in_tests_dir(&self) -> bool {
        self.path
            .components()
            .any(|c| c.as_os_str().to_string_lossy() == "tests")
    }

    /// Does any comment covering `line` satisfy `pred`?
    fn line_comment_matches(
        &self,
        line: u32,
        pred: &dyn Fn(&Tok) -> bool,
    ) -> bool {
        self.comments_by_line
            .get(&line)
            .is_some_and(|idxs| idxs.iter().any(|&i| pred(&self.toks[i])))
    }

    /// Walk the contiguous comment/attribute block directly above
    /// `line` (blank or code lines break the walk) looking for a
    /// comment satisfying `pred`.
    fn block_above_matches(
        &self,
        line: u32,
        pred: &dyn Fn(&Tok) -> bool,
    ) -> bool {
        let mut l = line.saturating_sub(1);
        while l >= 1 {
            let has_comment = self.comments_by_line.contains_key(&l);
            if has_comment && !self.code_lines.contains(&l) {
                if self.line_comment_matches(l, pred) {
                    return true;
                }
            } else if !self.attr_lines.contains(&l) {
                break;
            }
            l -= 1;
        }
        false
    }

    /// Is there a justifying comment adjacent to the token at
    /// `site_idx`? See the module docs for the adjacency definition.
    fn has_adjacent_comment(
        &self,
        site_idx: usize,
        pred: &dyn Fn(&Tok) -> bool,
    ) -> bool {
        let site_line = self.toks[site_idx].line;
        if self.line_comment_matches(site_line, pred)
            || self.block_above_matches(site_line, pred)
        {
            return true;
        }
        // Statement scope: scan back to the nearest `;`/`{`/`}`. A
        // matching comment passed on the way counts (mid-statement
        // justification); otherwise re-run the line checks at the
        // statement's first token.
        let mut anchor = None;
        let mut k = site_idx;
        while k > 0 {
            k -= 1;
            let t = &self.toks[k];
            if t.is_comment() {
                if pred(t) {
                    return true;
                }
                continue;
            }
            if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
                break;
            }
            anchor = Some(k);
        }
        if let Some(a) = anchor {
            let a_line = self.toks[a].line;
            if a_line != site_line
                && (self.line_comment_matches(a_line, pred)
                    || self.block_above_matches(a_line, pred))
            {
                return true;
            }
        }
        false
    }

    fn diag(
        &self,
        rule: &'static str,
        tok: &Tok,
        msg: impl Into<String>,
    ) -> Diag {
        Diag {
            rule,
            path: self.path.clone(),
            line: tok.line,
            col: tok.col,
            msg: msg.into(),
        }
    }
}

/// Find every `#[…]` / `#![…]` attribute in the stream.
fn collect_attrs(toks: &[Tok]) -> Vec<Attr> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if !toks[i].is_punct('#') {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if j < toks.len() && toks[j].is_punct('!') {
            j += 1;
        }
        if j >= toks.len() || !toks[j].is_punct('[') {
            i += 1;
            continue;
        }
        // Balanced-bracket scan for the closing `]`.
        let mut depth = 0usize;
        let mut name = String::new();
        let mut inner = Vec::new();
        let mut k = j;
        while k < toks.len() {
            let t = &toks[k];
            if t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(']') {
                depth -= 1;
                if depth == 0 {
                    k += 1;
                    break;
                }
            } else if t.kind == TokKind::Ident {
                if name.is_empty() {
                    name = t.text.clone();
                }
                inner.push(t.text.clone());
            }
            k += 1;
        }
        out.push(Attr { hash_idx: i, end_idx: k, name, inner });
        i = k;
    }
    out
}

/// Mark tokens inside the body of an item annotated `#[test]` or
/// `#[cfg(test)]` (the `mod tests { … }` convention and individual
/// test fns alike).
fn mark_test_regions(toks: &[Tok], attrs: &[Attr]) -> Vec<bool> {
    let mut test = vec![false; toks.len()];
    for a in attrs {
        let is_test_attr = a.inner == ["test"] || a.inner == ["cfg", "test"];
        if !is_test_attr {
            continue;
        }
        // Find the item body: the first `{` before any depth-0 `;`.
        let mut depth = 0i32;
        let mut k = a.end_idx;
        let mut body_start = None;
        while k < toks.len() {
            let t = &toks[k];
            if t.is_punct('(') || t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                depth -= 1;
            } else if t.is_punct('{') && depth == 0 {
                body_start = Some(k);
                break;
            } else if t.is_punct(';') && depth == 0 {
                break; // bodyless item (`mod x;`, `use …;`)
            }
            k += 1;
        }
        let Some(start) = body_start else { continue };
        let mut braces = 0i32;
        let mut k = start;
        while k < toks.len() {
            let t = &toks[k];
            if t.is_punct('{') {
                braces += 1;
            } else if t.is_punct('}') {
                braces -= 1;
            }
            test[k] = true;
            if braces == 0 {
                break;
            }
            k += 1;
        }
    }
    test
}

fn safety_pred(t: &Tok) -> bool {
    t.contains("SAFETY:") || t.contains("# Safety")
}

fn ordering_pred(t: &Tok) -> bool {
    t.contains("ORDERING:")
}

/// L001: `unsafe` without an adjacent `// SAFETY:` comment.
fn rule_l001(f: &SourceFile, out: &mut Vec<Diag>) {
    for (i, t) in f.toks.iter().enumerate() {
        if !t.is_ident("unsafe") {
            continue;
        }
        if f.has_adjacent_comment(i, &safety_pred) {
            continue;
        }
        let what = match f.toks.get(i + 1) {
            Some(n) if n.is_ident("fn") => "unsafe fn",
            Some(n) if n.is_ident("impl") => "unsafe impl",
            Some(n) if n.is_ident("trait") => "unsafe trait",
            _ => "unsafe block",
        };
        out.push(f.diag(
            "L001",
            t,
            format!("{what} without an adjacent `// SAFETY:` comment"),
        ));
    }
}

/// L002: `Ordering::Relaxed` (or a bare imported `Relaxed`) outside
/// `util::metrics` / test code without an adjacent `// ORDERING:`
/// comment.
fn rule_l002(f: &SourceFile, out: &mut Vec<Diag>) {
    if f.path_ends_with(&["util", "metrics.rs"]) || f.in_tests_dir() {
        return;
    }
    for (i, t) in f.toks.iter().enumerate() {
        if !t.is_ident("Relaxed") || f.test_tok[i] {
            continue;
        }
        if f.has_adjacent_comment(i, &ordering_pred) {
            continue;
        }
        out.push(f.diag(
            "L002",
            t,
            "Ordering::Relaxed without an adjacent `// ORDERING:` \
             justification comment",
        ));
    }
}

/// L003: `#[allow(…)]` without an adjacent justification comment.
fn rule_l003(f: &SourceFile, out: &mut Vec<Diag>) {
    let any_comment = |_: &Tok| true;
    for a in &f.attrs {
        if a.name != "allow" {
            continue;
        }
        let hash = &f.toks[a.hash_idx];
        let adjacent = f.line_comment_matches(hash.line, &any_comment)
            || f.block_above_matches(hash.line, &any_comment);
        if !adjacent {
            let what = a.inner.get(1).cloned().unwrap_or_default();
            out.push(f.diag(
                "L003",
                hash,
                format!(
                    "#[allow({what})] without an adjacent justification \
                     comment"
                ),
            ));
        }
    }
}

fn unquote(s: &str) -> &str {
    s.trim_start_matches(['b', 'r', '#'])
        .trim_start_matches('"')
        .trim_end_matches(['#'])
        .trim_end_matches('"')
}

/// L004, declaration side: the metric names registered in
/// `util::metrics`'s `REGISTRY` static, each of which must appear
/// exactly once. Returns the declared set when the file is the
/// registry file.
fn l004_declarations(
    f: &SourceFile,
    out: &mut Vec<Diag>,
) -> Option<HashSet<String>> {
    if !f.path_ends_with(&["util", "metrics.rs"]) {
        return None;
    }
    let start = f.toks.iter().position(|t| t.is_ident("REGISTRY"))?;
    let mut declared = HashSet::new();
    let mut depth = 0i32;
    for t in &f.toks[start..] {
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth -= 1;
        } else if t.is_punct(';') && depth == 0 {
            break;
        } else if t.kind == TokKind::Str {
            let name = unquote(&t.text).to_string();
            if !declared.insert(name.clone()) {
                out.push(f.diag(
                    "L004",
                    t,
                    format!("metric name {name:?} declared more than once"),
                ));
            }
        }
    }
    Some(declared)
}

/// L004, usage side: `.counter("…")` / `.hist("…")` string-literal
/// lookups collected per file for validation against the declared set.
fn l004_usages(f: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let t = &f.toks;
    for i in 0..t.len().saturating_sub(3) {
        if t[i].is_punct('.')
            && (t[i + 1].is_ident("counter") || t[i + 1].is_ident("hist"))
            && t[i + 2].is_punct('(')
            && t[i + 3].kind == TokKind::Str
        {
            out.push((unquote(&t[i + 3].text).to_string(), i + 3));
        }
    }
    out
}

/// L005, declaration side: the variants of `enum Frame` in
/// `service/frame.rs`, with their declaration token index.
fn l005_variants(f: &SourceFile) -> Option<Vec<(String, usize)>> {
    if !f.path_ends_with(&["service", "frame.rs"]) {
        return None;
    }
    let t = &f.toks;
    let mut start = None;
    for i in 0..t.len().saturating_sub(1) {
        if t[i].is_ident("enum") {
            // Skip comments between `enum` and its name.
            let name = (i + 1..t.len()).find(|&j| !t[j].is_comment())?;
            if t[name].is_ident("Frame") {
                start = (name + 1..t.len()).find(|&j| t[j].is_punct('{'));
                break;
            }
        }
    }
    let start = start?;
    let mut variants = Vec::new();
    let (mut braces, mut parens) = (1i32, 0i32);
    let mut expecting = true;
    let mut k = start + 1;
    while k < t.len() && braces > 0 {
        let tok = &t[k];
        if tok.is_comment() || f.attr_tok[k] {
            k += 1;
            continue;
        }
        if tok.is_punct('{') {
            braces += 1;
        } else if tok.is_punct('}') {
            braces -= 1;
        } else if tok.is_punct('(') || tok.is_punct('[') {
            parens += 1;
        } else if tok.is_punct(')') || tok.is_punct(']') {
            parens -= 1;
        } else if braces == 1 && parens == 0 {
            if tok.is_punct(',') {
                expecting = true;
            } else if expecting && tok.kind == TokKind::Ident {
                variants.push((tok.text.clone(), k));
                expecting = false;
            }
        }
        k += 1;
    }
    Some(variants)
}

/// L005, dispatch side: every `Frame::X` mention in a backend file.
fn l005_dispatched(f: &SourceFile) -> HashSet<String> {
    let t = &f.toks;
    let mut out = HashSet::new();
    for i in 0..t.len().saturating_sub(3) {
        if t[i].is_ident("Frame")
            && t[i + 1].is_punct(':')
            && t[i + 2].is_punct(':')
            && t[i + 3].kind == TokKind::Ident
        {
            out.insert(t[i + 3].text.clone());
        }
    }
    out
}

/// The three files that each must dispatch every `Frame` variant.
const BACKENDS: &[&[&str]] = &[
    &["service", "server.rs"],
    &["service", "reactor.rs"],
    &["service", "uring.rs"],
];

/// Run every rule over a set of lexed files. The cross-file rules
/// (L004, L005) activate when their anchor files (`util/metrics.rs`,
/// `service/frame.rs`) are part of the set.
pub fn lint_files(files: &[SourceFile]) -> Vec<Diag> {
    let mut out = Vec::new();
    for f in files {
        rule_l001(f, &mut out);
        rule_l002(f, &mut out);
        rule_l003(f, &mut out);
    }

    // L004 — one declaration set, usages validated everywhere.
    let mut declared: Option<HashSet<String>> = None;
    for f in files {
        if let Some(d) = l004_declarations(f, &mut out) {
            declared = Some(d);
        }
    }
    if let Some(declared) = &declared {
        for f in files {
            for (name, idx) in l004_usages(f) {
                if !declared.contains(&name) {
                    out.push(f.diag(
                        "L004",
                        &f.toks[idx],
                        format!(
                            "metric name {name:?} is not declared in the \
                             util::metrics REGISTRY"
                        ),
                    ));
                }
            }
        }
    }

    // L005 — codec variants vs the three backend dispatch paths.
    let mut variants: Option<(&SourceFile, Vec<(String, usize)>)> = None;
    for f in files {
        if let Some(v) = l005_variants(f) {
            variants = Some((f, v));
        }
    }
    if let Some((frame_file, variants)) = variants {
        for backend in BACKENDS {
            let Some(bf) = files.iter().find(|f| f.path_ends_with(backend))
            else {
                continue;
            };
            let dispatched = l005_dispatched(bf);
            for (name, idx) in &variants {
                if !dispatched.contains(name) {
                    out.push(frame_file.diag(
                        "L005",
                        &frame_file.toks[*idx],
                        format!(
                            "wire frame variant `{name}` is not dispatched \
                             in {}",
                            backend.join("/")
                        ),
                    ));
                }
            }
        }
    }

    out.sort_by(|a, b| {
        (&a.path, a.line, a.col, a.rule).cmp(&(&b.path, b.line, b.col, b.rule))
    });
    out
}

/// Lint in-memory sources (used by the fixture tests).
pub fn lint_sources(sources: &[(&Path, &str)]) -> Vec<Diag> {
    let files: Vec<SourceFile> = sources
        .iter()
        .map(|(p, s)| SourceFile::new(p.to_path_buf(), s))
        .collect();
    lint_files(&files)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(src: &str) -> Vec<Diag> {
        lint_sources(&[(Path::new("x/lib.rs"), src)])
    }

    fn rules_of(diags: &[Diag]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn l001_fires_on_bare_unsafe_block() {
        let d = lint_one("fn f() {\n    let x = unsafe { g() };\n}\n");
        assert_eq!(rules_of(&d), ["L001"]);
        assert_eq!((d[0].line, d[0].col), (2, 13));
    }

    #[test]
    fn l001_accepts_comment_above() {
        let src = "fn f() {\n    // SAFETY: g has no preconditions\n    \
                   let x = unsafe { g() };\n}\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn l001_accepts_comment_above_statement_start() {
        let src = "fn f() {\n    // SAFETY: fine\n    let x = g()\n        \
                   .map(|v| unsafe { h(v) });\n}\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn l001_accepts_trailing_same_line() {
        let src = "fn f() {\n    unsafe { g() } // SAFETY: fine\n}\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn l001_accepts_safety_doc_section_on_unsafe_fn() {
        let src = "/// Frees `p`.\n///\n/// # Safety\n/// `p` must be \
                   valid.\npub unsafe fn free(p: *mut u8) {}\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn l001_blank_line_breaks_adjacency() {
        let src = "// SAFETY: stale, far away\n\nfn f() {\n\n    unsafe { \
                   g() };\n}\n";
        assert_eq!(rules_of(&lint_one(src)), ["L001"]);
    }

    #[test]
    fn l001_ignores_unsafe_in_comments_and_strings() {
        let src = "// this mentions unsafe code\nfn f() {\n    let s = \
                   \"unsafe { }\";\n    let r = r#\"unsafe\"#;\n}\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn l002_fires_without_ordering_comment() {
        let d = lint_one("fn f(a: &A) {\n    a.x.load(Ordering::Relaxed);\n}\n");
        assert_eq!(rules_of(&d), ["L002"]);
    }

    #[test]
    fn l002_accepts_ordering_comment() {
        let src = "fn f(a: &A) {\n    // ORDERING: monotonic counter, no \
                   data published under it\n    \
                   a.x.load(Ordering::Relaxed);\n}\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn l002_exempts_metrics_and_tests_paths() {
        let src = "fn f(a: &A) { a.x.load(Ordering::Relaxed); }\n";
        let exempt = lint_sources(&[(Path::new("util/metrics.rs"), src)]);
        assert!(exempt.is_empty());
        let exempt = lint_sources(&[(Path::new("tests/stress.rs"), src)]);
        assert!(exempt.is_empty());
    }

    #[test]
    fn l002_exempts_cfg_test_modules() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(a: &A) { \
                   a.x.load(Ordering::Relaxed); }\n}\n";
        assert!(lint_one(src).is_empty());
        // …but the same code outside the module still fires.
        let src = "fn f(a: &A) { a.x.load(Ordering::Relaxed); }\n\
                   #[cfg(test)]\nmod tests {}\n";
        assert_eq!(rules_of(&lint_one(src)), ["L002"]);
    }

    #[test]
    fn l002_catches_bare_imported_relaxed() {
        let src = "use std::sync::atomic::Ordering::Relaxed;\nfn f(a: &A) \
                   { a.x.load(Relaxed); }\n";
        // Two sites: the use-import line is justification-free too —
        // both must carry a comment (the import line names the token).
        assert_eq!(rules_of(&lint_one(src)), ["L002", "L002"]);
    }

    #[test]
    fn l003_fires_on_unjustified_allow() {
        let d = lint_one("#[allow(dead_code)]\nfn f() {}\n");
        assert_eq!(rules_of(&d), ["L003"]);
        assert!(d[0].msg.contains("dead_code"));
    }

    #[test]
    fn l003_accepts_adjacent_comment() {
        let src = "// kept for the ffi layer\n#[allow(dead_code)]\nfn f() \
                   {}\n";
        assert!(lint_one(src).is_empty());
        let src = "#[allow(dead_code)] // kept for the ffi layer\nfn f() \
                   {}\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn l004_duplicate_declaration_fires() {
        let src = "pub static REGISTRY: &[(&str, M)] = &[\n    (\"a\", \
                   M::C),\n    (\"a\", M::C),\n];\n";
        let d = lint_sources(&[(Path::new("util/metrics.rs"), src)]);
        assert_eq!(rules_of(&d), ["L004"]);
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn l004_undeclared_usage_fires() {
        let reg = "pub static REGISTRY: &[(&str, M)] = &[(\"good\", \
                   M::C)];\n";
        let usage = "fn f(s: &S) { s.counter(\"goood\"); s.hist(\"good\"); \
                     }\n";
        let d = lint_sources(&[
            (Path::new("util/metrics.rs"), reg),
            (Path::new("bench/report.rs"), usage),
        ]);
        assert_eq!(rules_of(&d), ["L004"]);
        assert!(d[0].msg.contains("goood"));
    }

    #[test]
    fn l005_missing_backend_dispatch_fires() {
        let frame = "pub enum Frame {\n    /// docs\n    Batch(Vec<Op>),\n    \
                     Stats,\n    Quit,\n}\n";
        let hits = "fn d(f: Frame) { match f { Frame::Batch(_) => {}, \
                    Frame::Stats => {}, Frame::Quit => {} } }\n";
        let misses = "fn d(f: Frame) { match f { Frame::Batch(_) => {}, _ \
                      => {} } }\n";
        let d = lint_sources(&[
            (Path::new("service/frame.rs"), frame),
            (Path::new("service/server.rs"), hits),
            (Path::new("service/reactor.rs"), hits),
            (Path::new("service/uring.rs"), misses),
        ]);
        assert_eq!(rules_of(&d), ["L005", "L005"]);
        assert!(d[0].msg.contains("uring"));
        assert!(d[0].msg.contains("`Stats`"));
        assert!(d[1].msg.contains("`Quit`"));
        // Span points at the variant declaration in frame.rs.
        assert!(d[0].path.ends_with("service/frame.rs"));
        assert_eq!(d[0].line, 4);
    }

    #[test]
    fn l005_silent_when_all_dispatch() {
        let frame = "pub enum Frame { Batch(Vec<Op>), Quit }\n";
        let hits =
            "fn d(f: Frame) { matches!(f, Frame::Batch(_) | Frame::Quit); }\n";
        let d = lint_sources(&[
            (Path::new("service/frame.rs"), frame),
            (Path::new("service/server.rs"), hits),
            (Path::new("service/reactor.rs"), hits),
            (Path::new("service/uring.rs"), hits),
        ]);
        assert!(d.is_empty());
    }
}
