//! In-tree static analysis: the `crh lint` concurrency lint pass.
//!
//! The paper's table lives or dies on the correctness of its atomic
//! orderings and unsafe publication sites — exactly the properties
//! neither rustc nor clippy checks and a human reviewer can't reliably
//! eyeball across a growing crate. This subsystem makes the crate's
//! memory-model documentation *machine-checked*: a lightweight Rust
//! lexer ([`lexer`]) feeds a rules engine ([`rules`]) that enforces
//! the conventions the codebase audit established:
//!
//! * **L001** — every `unsafe` block/fn/impl carries an adjacent
//!   `// SAFETY:` comment (or `# Safety` doc section) stating the
//!   invariant that makes it sound.
//! * **L002** — every `Ordering::Relaxed` outside `util::metrics` and
//!   test code carries an adjacent `// ORDERING:` comment justifying
//!   why no happens-before edge is needed.
//! * **L003** — every `#[allow(…)]` opt-out carries an adjacent
//!   justification comment.
//! * **L004** — metric name strings are declared exactly once in the
//!   `util::metrics` registry, and every string lookup names a
//!   declared metric (a typo'd counter can't silently drift out of
//!   the `STATS` schema).
//! * **L005** — every wire `Frame` variant the shared codec can yield
//!   is dispatched by all three front-ends (threads/reactor/uring), so
//!   a new verb can't ship on only one backend.
//!
//! Run it as `crh lint [path…]` (defaults to `src`, `tests`,
//! `benches`, and `../examples` relative to the working directory,
//! skipping `tests/lint_fixtures`); CI runs it as a blocking lane.
//! The engine is dependency-free and deliberately small: a token
//! stream plus adjacency rules, not a parser — see `rules` for the
//! exact adjacency definition.

pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

pub use rules::{lint_files, lint_sources, Diag, SourceFile};

use crate::util::error::{Error, Result};

/// Directories never walked: build output, VCS internals, and the
/// deliberately-violating lint fixtures (they are linted explicitly by
/// the test tier, never as part of the tree).
const SKIP_DIRS: &[&str] = &["target", ".git", "lint_fixtures"];

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .collect::<std::io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for path in entries {
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        if path.is_dir() {
            if !SKIP_DIRS.contains(&name.as_str()) {
                walk(&path, out)?;
            }
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Collect every `.rs` file under `paths` (files are taken as-is,
/// directories are walked recursively).
pub fn collect_rs_files(paths: &[PathBuf]) -> Result<Vec<PathBuf>> {
    let mut files = Vec::new();
    for p in paths {
        if p.is_dir() {
            walk(p, &mut files)?;
        } else if p.is_file() {
            files.push(p.clone());
        } else {
            return Err(Error::msg(format!("lint: no such path {p:?}")));
        }
    }
    files.sort();
    files.dedup();
    Ok(files)
}

/// Lint every `.rs` file under `paths`. Diagnostics come back sorted
/// by (path, line, column).
pub fn lint_paths(paths: &[PathBuf]) -> Result<Vec<Diag>> {
    let mut files = Vec::new();
    for path in collect_rs_files(paths)? {
        let src = std::fs::read_to_string(&path)?;
        files.push(SourceFile::new(path, &src));
    }
    Ok(lint_files(&files))
}

/// The default lint scope when `crh lint` gets no path arguments:
/// the crate source plus its test/bench/example trees, whichever
/// exist relative to the working directory (CI runs from `rust/`).
pub fn default_paths() -> Vec<PathBuf> {
    ["src", "tests", "benches", "../examples"]
        .iter()
        .map(PathBuf::from)
        .filter(|p| p.is_dir())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collect_skips_fixture_and_target_dirs() {
        let dir = std::env::temp_dir().join(format!(
            "crh_lint_walk_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("a/lint_fixtures")).unwrap();
        std::fs::create_dir_all(dir.join("target")).unwrap();
        std::fs::write(dir.join("a/keep.rs"), "fn f() {}\n").unwrap();
        std::fs::write(dir.join("a/skip.txt"), "not rust\n").unwrap();
        std::fs::write(dir.join("a/lint_fixtures/bad.rs"), "unsafe {}\n")
            .unwrap();
        std::fs::write(dir.join("target/gen.rs"), "unsafe {}\n").unwrap();
        let files = collect_rs_files(&[dir.clone()]).unwrap();
        assert_eq!(files, vec![dir.join("a/keep.rs")]);
        let diags = lint_paths(&[dir.clone()]).unwrap();
        assert!(diags.is_empty(), "{diags:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_path_is_an_error() {
        assert!(lint_paths(&[PathBuf::from("/no/such/crh/path")]).is_err());
    }
}
