//! Multi-key transaction tier: `apply_txn` against a sequential
//! oracle on every map kind, concurrent transfer conservation across
//! shard counts, mixed txn/single-op linearizability (one atomic
//! window per committed transaction), transactions racing a live
//! two-generation migration, and the `T <n>` wire frame round-tripped
//! byte-identically through all three front-end backends.

use std::collections::HashMap;
use std::sync::Arc;

use crh::maps::txn::apply_txn_occ;
use crh::maps::{ConcurrentMap, MapError, MapKind, MapOp, MapReply};
use crh::service::server::{self, Client};
use crh::service::Backend;
use crh::util::linearize::{is_txn_linearizable, record_txn_history};
use crh::util::prop::scaled;
use crh::util::rng::Rng;

/// 2^62: `fetch_add` arithmetic is mod this, so adding `M - x`
/// subtracts `x`.
const M: u64 = 1 << 62;

/// Sequential reply semantics — the oracle `apply_txn` is checked
/// against, op by op over a `HashMap`.
fn oracle_reply(state: &mut HashMap<u64, u64>, op: MapOp) -> MapReply {
    match op {
        MapOp::Get(k) => MapReply::Value(state.get(&k).copied()),
        MapOp::Insert(k, v) => MapReply::Prev(state.insert(k, v)),
        MapOp::Remove(k) => MapReply::Removed(state.remove(&k)),
        MapOp::CmpEx(k, e, n) => {
            let cur = state.get(&k).copied();
            if cur == e {
                match n {
                    Some(v) => {
                        state.insert(k, v);
                    }
                    None => {
                        state.remove(&k);
                    }
                }
                MapReply::CmpEx(Ok(()))
            } else {
                MapReply::CmpEx(Err(cur))
            }
        }
        MapOp::GetOrInsert(k, v) => {
            let cur = state.get(&k).copied();
            if cur.is_none() {
                state.insert(k, v);
            }
            MapReply::Existing(cur)
        }
        MapOp::FetchAdd(k, d) => {
            let cur = state.get(&k).copied();
            state.insert(k, cur.unwrap_or(0).wrapping_add(d) & (M - 1));
            MapReply::Added(cur)
        }
    }
}

fn random_op(rng: &mut Rng, keys: u64) -> MapOp {
    let k = 1 + rng.below(keys);
    let opt = |rng: &mut Rng| {
        if rng.below(3) == 0 {
            None
        } else {
            Some(rng.below(4))
        }
    };
    match rng.below(6) {
        0 => MapOp::Get(k),
        1 => MapOp::Insert(k, rng.below(4)),
        2 => MapOp::Remove(k),
        3 => MapOp::FetchAdd(k, 1 + rng.below(3)),
        _ => MapOp::CmpEx(k, opt(rng), opt(rng)),
    }
}

/// Single-threaded `apply_txn` vs the oracle: committed replies must
/// match a sequential overlay replay exactly, an abort must leave the
/// table untouched (checked implicitly — the oracle is not advanced
/// and every later op revalidates the full state), and the final
/// contents must agree key by key. Structural op mixes are allowed to
/// report `TxnConflict` (intrinsically colliding plans); pin-only
/// transactions never may.
fn check_oracle(kind: MapKind) {
    let m = kind.build(10);
    let mut oracle: HashMap<u64, u64> = HashMap::new();
    let mut rng = Rng::for_thread(0xF18, 0);
    let keys = 16u64;
    let (mut commits, mut conflicts) = (0u64, 0u64);
    for i in 0..scaled(400) {
        if rng.below(3) == 0 {
            // A lone op through the single-key surface.
            let op = random_op(&mut rng, keys);
            let got = match op {
                MapOp::Get(k) => MapReply::Value(m.get(k)),
                MapOp::Insert(k, v) => MapReply::Prev(m.insert(k, v)),
                MapOp::Remove(k) => MapReply::Removed(m.remove(k)),
                MapOp::CmpEx(k, e, n) => {
                    MapReply::CmpEx(m.compare_exchange(k, e, n))
                }
                MapOp::GetOrInsert(k, v) => {
                    MapReply::Existing(m.get_or_insert(k, v))
                }
                MapOp::FetchAdd(k, d) => MapReply::Added(m.fetch_add(k, d)),
            };
            let want = oracle_reply(&mut oracle, op);
            assert_eq!(got, want, "{}: lone op {i} ({op:?})", kind.name());
            continue;
        }
        let len = 1 + rng.below(4) as usize;
        let ops: Vec<MapOp> =
            (0..len).map(|_| random_op(&mut rng, keys)).collect();
        match m.apply_txn(&ops) {
            Ok(replies) => {
                commits += 1;
                assert_eq!(replies.len(), ops.len());
                for (j, (&op, &got)) in
                    ops.iter().zip(replies.iter()).enumerate()
                {
                    let want = oracle_reply(&mut oracle, op);
                    assert_eq!(
                        got,
                        want,
                        "{}: txn {i} op {j} ({op:?})",
                        kind.name()
                    );
                }
            }
            Err(MapError::TxnConflict) => {
                // All-or-nothing: nothing changed, oracle stays.
                conflicts += 1;
                let structural = {
                    let mut overlay = oracle.clone();
                    ops.iter().any(|&op| {
                        let before = overlay.contains_key(&op.key());
                        oracle_reply(&mut overlay, op);
                        before != overlay.contains_key(&op.key())
                    })
                };
                assert!(
                    structural && ops.len() > 1,
                    "{}: pin-only txn {i} conflicted uncontended: {ops:?}",
                    kind.name()
                );
            }
            Err(e) => panic!("{}: txn {i} failed: {e}", kind.name()),
        }
    }
    assert!(
        commits > conflicts,
        "{}: {} commits vs {} conflicts — engine aborts too much",
        kind.name(),
        commits,
        conflicts
    );
    for k in 1..=keys {
        assert_eq!(
            m.get(k),
            oracle.get(&k).copied(),
            "{}: final state diverged at key {k}",
            kind.name()
        );
    }
}

#[test]
fn txn_matches_serial_oracle_every_map_kind() {
    for kind in MapKind::all() {
        check_oracle(kind);
    }
}

/// The OCC baseline commits and matches the same oracle when
/// uncontended (its weaker isolation only shows under concurrency).
#[test]
fn occ_baseline_matches_serial_oracle() {
    let m = MapKind::KCasRhMap.build(10);
    let mut oracle: HashMap<u64, u64> = HashMap::new();
    let mut rng = Rng::for_thread(0x0CC, 0);
    for i in 0..scaled(300) {
        let len = 1 + rng.below(4) as usize;
        let ops: Vec<MapOp> =
            (0..len).map(|_| random_op(&mut rng, 16)).collect();
        let replies = apply_txn_occ(m.as_ref(), &ops)
            .unwrap_or_else(|e| panic!("uncontended OCC txn {i} failed: {e}"));
        for (&op, &got) in ops.iter().zip(replies.iter()) {
            assert_eq!(got, oracle_reply(&mut oracle, op), "OCC txn {i}");
        }
    }
    for k in 1..=16u64 {
        assert_eq!(m.get(k), oracle.get(&k).copied());
    }
}

/// Concurrent two-leg transfers between pre-seeded accounts: every
/// `apply_txn` must commit (pin-only op sets retry races internally),
/// and the grand total must be conserved mod 2^62 — the invariant a
/// torn or half-applied commit would break. Swept across shard counts,
/// so single-shard and cross-shard commits both run.
fn check_transfer_conservation(build: impl Fn() -> Box<dyn ConcurrentMap>) {
    const ACCOUNTS: u64 = 32;
    const SEED_BALANCE: u64 = 1_000_000;
    let m = build();
    for k in 1..=ACCOUNTS {
        assert_eq!(m.insert(k, SEED_BALANCE), None);
    }
    let transfers: u64 = scaled(4_000);
    std::thread::scope(|s| {
        for tid in 0..4u64 {
            let m = m.as_ref();
            s.spawn(move || {
                let mut rng = Rng::for_thread(0xBA7A + tid, tid);
                for i in 0..transfers {
                    let src = 1 + rng.below(ACCOUNTS);
                    let mut dst = 1 + rng.below(ACCOUNTS);
                    while dst == src {
                        dst = 1 + rng.below(ACCOUNTS);
                    }
                    let amt = 1 + rng.below(100);
                    let ops = [
                        MapOp::FetchAdd(src, M - amt), // debit
                        MapOp::FetchAdd(dst, amt),     // credit
                    ];
                    let replies = m.apply_txn(&ops).unwrap_or_else(|e| {
                        panic!("thread {tid} transfer {i} aborted: {e}")
                    });
                    assert_eq!(replies.len(), 2);
                }
            });
        }
    });
    let total: u128 = (1..=ACCOUNTS)
        .map(|k| m.get(k).expect("account vanished") as u128)
        .sum();
    assert_eq!(
        total % (M as u128),
        (ACCOUNTS * SEED_BALANCE) as u128,
        "{}: money created or destroyed",
        m.name()
    );
}

#[test]
fn transfers_conserve_total_kcas_across_shards() {
    for shards in [1u32, 4, 16] {
        check_transfer_conservation(|| {
            MapKind::ShardedKCasRhMap { shards }.build(12)
        });
    }
}

#[test]
fn transfers_conserve_total_2pl() {
    check_transfer_conservation(|| {
        MapKind::ShardedLockedLpMap { shards: 4 }.build(12)
    });
}

#[test]
fn transfers_conserve_total_resizable() {
    check_transfer_conservation(|| MapKind::IncResizableRhMap.build(12));
}

/// Mixed histories — lone ops racing multi-key transactions — must
/// linearize with each committed transaction as ONE atomic multi-key
/// window (a reader observing half a transaction's writes fails the
/// checker).
fn check_txn_linearizable(
    build: impl Fn() -> Box<dyn ConcurrentMap>,
    windows: u64,
    name: &str,
) {
    for w in 0..windows {
        let m = build();
        let mut initial = Vec::new();
        for k in 1..=3u64 {
            m.insert(k, k);
            initial.push((k, k));
        }
        let h = record_txn_history(m.as_ref(), 3, 8, 6, 0x7A9 + w);
        assert_eq!(h.len(), 24, "{name}: short history");
        assert!(
            is_txn_linearizable(&initial, &h),
            "{name}: non-atomic transaction window {w}: {h:#?}"
        );
    }
}

#[test]
fn txn_histories_linearize_kcas_rh_map() {
    check_txn_linearizable(|| MapKind::KCasRhMap.build(7), 40, "kcas-rh-map");
}

#[test]
fn txn_histories_linearize_locked_lp_map() {
    check_txn_linearizable(
        || MapKind::LockedLpMap.build(7),
        40,
        "locked-lp-map",
    );
}

#[test]
fn txn_histories_linearize_sharded_kcas_rh_map() {
    for shards in [1u32, 4, 16] {
        check_txn_linearizable(
            || MapKind::ShardedKCasRhMap { shards }.build(8),
            15,
            &format!("sharded-kcas-rh-map:{shards}"),
        );
    }
}

/// Transactions recorded while a two-generation migration is in
/// flight: the commit must stay atomic across frozen source cells and
/// the freeze/transfer protocol, not just on a settled table.
#[test]
fn txn_histories_linearize_mid_migration() {
    use crh::maps::resizable::ResizableRobinHoodMap;
    for w in 0..15u64 {
        // 4096 buckets = 64 migration stripes: a window's handful of
        // helping ops cannot drain the migration mid-recording.
        let m = ResizableRobinHoodMap::with_threshold(12, 0.4);
        let mut filler = 1000u64;
        while !m.migration_active() {
            m.insert(filler, filler);
            filler += 1;
        }
        let mut initial = Vec::new();
        for k in 1..=3u64 {
            m.insert(k, k);
            initial.push((k, k));
        }
        assert!(
            m.migration_active(),
            "window {w}: migration drained before recording"
        );
        let h = record_txn_history(&m, 3, 8, 6, 0x9A13 + w);
        assert!(
            is_txn_linearizable(&initial, &h),
            "mid-migration window {w}: {h:#?}"
        );
    }
}

/// Transfers driven straight into an in-flight migration: conservation
/// holds even while every commit may span the old and new generation.
#[test]
fn transfers_conserve_total_mid_migration() {
    use crh::maps::resizable::ResizableRobinHoodMap;
    check_transfer_conservation(|| {
        let m = ResizableRobinHoodMap::with_threshold(12, 0.4);
        let mut filler = 1000u64;
        while !m.migration_active() {
            m.insert(filler, filler);
            filler += 1;
        }
        Box::new(m)
    });
}

// ---- `T <n>` wire frames across the three front-ends ----

fn service_map() -> Arc<dyn ConcurrentMap> {
    Arc::from(MapKind::ShardedKCasRhMap { shards: 4 }.build(12))
}

/// A fixed raw trace exercising the `T <n>` grammar: a multi-key
/// commit with value- and CAS-shaped replies (keys pre-seeded so every
/// leg is a pin — pin-only op sets can never intrinsically conflict,
/// keeping the trace deterministic), a lone op queued *behind* a txn
/// in the same write (program order must hold), the `T 0` and
/// bad-member reject paths, a single-key structural commit, and a
/// trailing batch frame proving the stream stays in sync. Delivered in
/// 7-byte chunks so txn frames also reassemble across read boundaries.
const TXN_TRACE: &str = "P 1 10\n\
    P 2 1\n\
    T 4\nA 1 5\nP 2 7\nG 2\nC 1 15 20\n\
    G 1\n\
    T 1\nP 9 9\nG 9\n\
    T 0\n\
    T 2\nG 0\nG 1\n\
    T 1\nC 2 7 -\n\
    G 2\n\
    B 2\nG 9\nD 9\n";

const TXN_TRACE_REPLIES: [&str; 11] = [
    "-",
    "-",
    "10 1 7 OK",
    "20",
    "-",
    "9",
    "ERR bad batch size",
    "ERR key out of range",
    "OK",
    "-",
    "9 9",
];

fn run_txn_trace(backend: Backend) -> Vec<String> {
    let h = backend.spawn(service_map(), 2).unwrap();
    let mut c = Client::connect(h.addr()).unwrap();
    for chunk in TXN_TRACE.as_bytes().chunks(7) {
        c.send_raw(chunk).unwrap();
    }
    let replies: Vec<String> = (0..TXN_TRACE_REPLIES.len())
        .map(|i| {
            c.read_reply_line().unwrap_or_else(|e| {
                panic!("{}: reply {i} missing: {e}", backend.name())
            })
        })
        .collect();
    h.shutdown();
    replies
}

#[test]
fn txn_trace_byte_identical_across_backends() {
    let want: Vec<String> =
        TXN_TRACE_REPLIES.iter().map(|s| s.to_string()).collect();
    for backend in Backend::ALL {
        assert_eq!(
            run_txn_trace(backend),
            want,
            "backend {} diverged on the fixed txn trace",
            backend.name()
        );
    }
}

/// The typed client surface: `Client::txn` round-trips every reply
/// shape, and `batch_typed` (rebased on the same reply-segment parser)
/// still works on the same connection.
#[test]
fn typed_client_txn_round_trip() {
    let h = server::spawn_server(service_map()).unwrap();
    let mut c = Client::connect(h.addr()).unwrap();
    // Pre-seed both keys so the multi-key txns below are pin-only
    // (deterministically conflict-free).
    assert_eq!(c.request_line("P 3 1").unwrap(), "-");
    assert_eq!(c.request_line("P 4 40").unwrap(), "-");
    let r = c
        .txn(&[
            MapOp::Insert(3, 30),
            MapOp::FetchAdd(3, 5),
            MapOp::Get(3),
            MapOp::GetOrInsert(4, 99),
        ])
        .unwrap();
    assert_eq!(
        r,
        vec![
            MapReply::Prev(Some(1)),
            MapReply::Added(Some(30)),
            MapReply::Value(Some(35)),
            MapReply::Existing(Some(40)),
        ]
    );
    let r = c
        .txn(&[
            MapOp::CmpEx(3, Some(35), Some(36)),
            MapOp::CmpEx(3, Some(99), None),
        ])
        .unwrap();
    assert_eq!(
        r,
        vec![MapReply::CmpEx(Ok(())), MapReply::CmpEx(Err(Some(36)))]
    );
    let r = c.batch_typed(&[MapOp::Get(3), MapOp::Remove(4)]).unwrap();
    assert_eq!(
        r,
        vec![MapReply::Value(Some(36)), MapReply::Removed(Some(40))]
    );
    h.shutdown();
}
