//! L005 fixture backend: dispatches every `Frame` variant, including
//! the transaction frame.

pub fn dispatch(f: Frame) {
    match f {
        Frame::Batch(ops) => drop(ops),
        Frame::Txn(ops) => drop(ops),
        Frame::Stop => {}
    }
}
