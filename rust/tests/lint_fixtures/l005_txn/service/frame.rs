//! L005 fixture codec for the `T <n>` transaction frame: `Txn` is
//! dispatched by two of the three backends but not
//! `service/reactor.rs`, so L005 must fire once, anchored here. A new
//! wire verb that only some backends learn is exactly the regression
//! this rule exists to catch.
//!
//! Never compiled — linted explicitly by `tests/lint.rs`.

pub enum Frame {
    Batch(Vec<Op>),
    Txn(Vec<Op>),
    Stop,
}
