//! L005 fixture backend: misses `Frame::Txn` — the catch-all arm
//! would silently drop every `T <n>` transaction on this backend,
//! which the compiler cannot see but L005 can.

pub fn dispatch(f: Frame) {
    match f {
        Frame::Batch(ops) => drop(ops),
        Frame::Stop => {}
        _ => {}
    }
}
