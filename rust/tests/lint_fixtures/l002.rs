//! L002 fixture: the first `Ordering::Relaxed` is documented and must
//! not fire; the second has no adjacent `// ORDERING:` comment and
//! must; the one inside `#[cfg(test)]` is exempt and must not.
//!
//! Never compiled — linted explicitly by `tests/lint.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};

pub static N: AtomicUsize = AtomicUsize::new(0);

pub fn documented() -> usize {
    // ORDERING: fixture — standalone counter guarding no other memory.
    N.load(Ordering::Relaxed)
}

pub fn undocumented() -> usize {
    N.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        super::N.store(1, super::Ordering::Relaxed);
    }
}
