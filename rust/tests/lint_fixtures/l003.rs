//! L003 fixture: the first `#[allow(…)]` carries a justification
//! comment and must not fire; the second has none and must.
//!
//! Never compiled — linted explicitly by `tests/lint.rs`.

// Fixture type kept deliberately unused to exercise the lint.
#[allow(dead_code)]
pub struct Documented;

#[allow(dead_code)]
pub struct Undocumented;
