//! L001 fixture: the first `unsafe` block is documented and must not
//! fire; the second has no adjacent `// SAFETY:` comment and must.
//!
//! Never compiled — linted explicitly by `tests/lint.rs`.

pub fn documented(p: *const u32) -> u32 {
    // SAFETY: fixture — the caller guarantees `p` is valid and aligned.
    unsafe { *p }
}

pub fn undocumented(p: *const u32) -> u32 {
    unsafe { *p }
}
