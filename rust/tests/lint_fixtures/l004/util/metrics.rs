//! L004 fixture registry (path-anchored at `util/metrics.rs`): the
//! duplicate `"ops_total"` entry must fire once.
//!
//! Never compiled — linted explicitly by `tests/lint.rs`.

pub static REGISTRY: &[&str] = &[
    "ops_total",
    "queue_depth",
    "ops_total",
];
