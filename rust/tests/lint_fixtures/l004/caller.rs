//! L004 fixture user: the first lookup names a declared metric and
//! must not fire; the `"ops_totle"` typo must.
//!
//! Never compiled — linted explicitly by `tests/lint.rs`.

pub fn read(m: &Metrics) -> u64 {
    m.counter("ops_total").get() + m.counter("ops_totle").get()
}
