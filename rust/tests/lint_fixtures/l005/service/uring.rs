//! L005 fixture backend: misses `Frame::Stop` — the catch-all arm
//! hides the gap from the compiler, which is exactly what L005 exists
//! to catch.

pub fn dispatch(f: Frame) {
    match f {
        Frame::Get(k) => drop(k),
        Frame::Put(k, v) => drop((k, v)),
        _ => {}
    }
}
