//! L005 fixture backend: dispatches every `Frame` variant.

pub fn dispatch(f: Frame) {
    match f {
        Frame::Get(k) => drop(k),
        Frame::Put(k, v) => drop((k, v)),
        Frame::Stop => {}
    }
}
