//! L005 fixture codec (path-anchored at `service/frame.rs`): the
//! `Stop` variant is dispatched by two of the three backends but not
//! `service/uring.rs`, so L005 must fire once, anchored here.
//!
//! Never compiled — linted explicitly by `tests/lint.rs`.

pub enum Frame {
    Get(u64),
    Put(u64, u64),
    Stop,
}
