//! Telemetry-plane tier: the `util::metrics` primitives under real
//! concurrency (counter monotonicity, histogram bucket boundaries,
//! snapshot-diff correctness), the `CRH_METRICS=0` disabled path being
//! invisible end-to-end, and the `STATS` wire verb answering
//! byte-identically on both TCP front-ends.
//!
//! The metrics gate and registry are process-global, so every test in
//! this binary serializes on [`lock_gate`]; this test file owns its own
//! process (Cargo builds each integration test as a separate binary),
//! so nothing outside this file races the gate.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crh::maps::{ConcurrentMap, MapKind};
use crh::service::reactor;
use crh::service::server::{self, Client};
use crh::util::json::Json;
use crh::util::metrics::{
    self, metrics, set_enabled, snapshot, stats_line, Counter, Hist,
};

static GATE_LOCK: Mutex<()> = Mutex::new(());

/// Serialize gate flips and global-registry assertions across the
/// parallel test threads. A panicking holder must not wedge the rest
/// of the file, so poison is ignored.
fn lock_gate() -> MutexGuard<'static, ()> {
    GATE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Drop guard: tests that disable the gate re-enable it on every exit
/// path (the default state for the rest of the binary).
struct Reenable;

impl Drop for Reenable {
    fn drop(&mut self) {
        set_enabled(true);
    }
}

fn map(size_log2: u32) -> Arc<dyn ConcurrentMap> {
    Arc::from(MapKind::ShardedKCasRhMap { shards: 2 }.build(size_log2))
}

/// Writers hammer one sharded counter from many threads while a reader
/// polls it: every observed value is non-decreasing (monotonic under
/// concurrency), and the final total is exact — no lost updates across
/// shards.
#[test]
fn counter_is_monotonic_under_concurrent_hammering() {
    let _g = lock_gate();
    set_enabled(true);
    const THREADS: u64 = 8;
    let per: u64 = crh::util::prop::scaled(50_000);
    let c = Arc::new(Counter::new());
    let done = Arc::new(AtomicBool::new(false));

    let reader = {
        let c = Arc::clone(&c);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let mut last = 0u64;
            while !done.load(Ordering::Acquire) {
                let now = c.get();
                assert!(now >= last, "counter went backwards: {last} -> {now}");
                last = now;
            }
        })
    };
    let writers: Vec<_> = (0..THREADS)
        .map(|t| {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                for i in 0..per {
                    // Exercise both entry points.
                    if (i + t) % 2 == 0 {
                        c.incr();
                    } else {
                        c.add(1);
                    }
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    done.store(true, Ordering::Release);
    reader.join().unwrap();
    assert_eq!(c.get(), THREADS * per, "increments were lost");
}

/// Bucket `b` holds `[2^b, 2^(b+1))`, 0 shares bucket 0 with 1, and
/// values past the last bucket clamp into it — the exact `LatencyHist`
/// scheme the bench driver uses, so the two planes stay comparable.
#[test]
fn hist_bucket_boundaries_follow_powers_of_two() {
    let _g = lock_gate();
    set_enabled(true);
    let h = Hist::new();
    for v in [0, 1, 2, 3, 4, 7, 8, 1 << 46, u64::MAX] {
        h.record(v);
    }
    let s = h.snapshot();
    assert_eq!(s.buckets[0], 2, "0 and 1 share bucket 0");
    assert_eq!(s.buckets[1], 2, "[2,4) is bucket 1");
    assert_eq!(s.buckets[2], 2, "[4,8) is bucket 2");
    assert_eq!(s.buckets[3], 1, "8 opens bucket 3");
    assert_eq!(s.buckets[46], 1);
    assert_eq!(s.buckets[47], 1, "u64::MAX clamps into the last bucket");
    assert_eq!(s.count(), 9);
    assert_eq!(s.max, u64::MAX);
    // Quantiles report the geometric bucket midpoint, clamped to max.
    assert_eq!(s.quantile(0.01), 1);
}

/// A snapshot diff spanning a region reports exactly that region's
/// activity — bumped metrics show their delta, untouched ones read 0 —
/// and `measured` reduces the delta to the headline cell series.
#[test]
fn snapshot_diff_isolates_a_region() {
    let _g = lock_gate();
    set_enabled(true);
    let before = snapshot();
    metrics().rh_displacements.add(11);
    metrics().batch_size.record(32);
    metrics().batch_size.record(33);
    let d = snapshot().diff(&before);
    assert_eq!(d.counter("rh_displacements"), 11);
    assert_eq!(d.counter("server_panics"), 0, "untouched counter moved");
    let bs = d.hist("batch_size").unwrap();
    assert_eq!(bs.count(), 2, "exactly the two recorded batch sizes");

    let ((), mets) = metrics::measured(|| {
        metrics().resize_stripes_drained.add(2);
        metrics().resize_keys_migrated.add(5);
    });
    let get = |k: &str| {
        mets.iter().find(|(n, _)| n == k).map(|&(_, v)| v)
    };
    assert_eq!(get("stripes_drained"), Some(2.0));
    assert_eq!(get("keys_migrated"), Some(5.0));
}

/// With the gate off, a full wire round trip (connect, put, get,
/// shutdown) through a real map moves *no* registered metric: the
/// disabled path is invisible, and `cell_metrics` refuses to emit an
/// all-zero section that would read as "measured, and zero".
#[test]
fn disabled_gate_is_invisible_end_to_end() {
    let _g = lock_gate();
    set_enabled(false);
    let _re = Reenable;
    let before = snapshot();

    let h = server::spawn_server(map(12)).unwrap();
    let mut c = Client::connect(h.addr()).unwrap();
    assert_eq!(c.request_line("P 1 10").unwrap(), "-");
    assert_eq!(c.request_line("G 1").unwrap(), "10");
    h.shutdown();

    let d = snapshot().diff(&before);
    for (name, v) in &d.counters {
        assert_eq!(*v, 0, "counter {name} moved while disabled");
    }
    for (name, hist) in &d.hists {
        assert_eq!(hist.count(), 0, "hist {name} recorded while disabled");
    }
    assert!(
        metrics::cell_metrics(&d).is_empty(),
        "cell metrics must be empty while disabled"
    );
}

/// Both front-ends answer `STATS` through the shared codec and the
/// shared renderer: with the gate frozen between the two reads, the
/// replies are byte-identical to each other and to an in-process
/// `stats_line()`, and parse as the documented JSON shape.
#[test]
fn stats_round_trips_identically_on_both_backends() {
    let _g = lock_gate();
    set_enabled(true);

    let th = server::spawn_server(map(12)).unwrap();
    let eh = reactor::spawn_server_epoll(map(12), 1).unwrap();
    let mut tc = Client::connect(th.addr()).unwrap();
    let mut ec = Client::connect(eh.addr()).unwrap();
    // Warm real traffic through both so the snapshot is non-trivial.
    assert_eq!(tc.request_line("P 3 30").unwrap(), "-");
    assert_eq!(ec.request_line("G 3").unwrap(), "-");

    // Freeze: the two STATS reads (which themselves decode frames and
    // move wire bytes) must not perturb the snapshot they render.
    set_enabled(false);
    let _re = Reenable;
    let a = tc.stats().unwrap();
    let b = ec.stats().unwrap();
    assert_eq!(a, b, "backends rendered different STATS replies");
    assert_eq!(a, stats_line(), "wire reply differs from in-process line");

    let j = Json::parse(&a).expect("STATS reply parses as JSON");
    assert_eq!(j.get("enabled"), Some(&Json::Bool(false)));
    let counters = j.get("counters").and_then(Json::as_obj).unwrap();
    assert!(
        counters.iter().any(|(k, _)| k == "kcas_attempts"),
        "counters section lost the kcas series"
    );
    let hists = j.get("histograms").and_then(Json::as_obj).unwrap();
    let probe = hists
        .iter()
        .find(|(k, _)| k == "probe_len_read")
        .map(|(_, v)| v)
        .expect("probe_len_read histogram missing");
    for field in ["count", "p50", "p99", "max"] {
        assert!(probe.get(field).is_some(), "histogram lost {field}");
    }
    th.shutdown();
    eh.shutdown();
}

/// With the gate on, real wire activity registers: decoded frames,
/// batch sizes, and per-direction byte counters all move, and the
/// batch reply comes back correct while being counted.
#[test]
fn enabled_gate_counts_wire_activity() {
    let _g = lock_gate();
    set_enabled(true);
    let before = snapshot();

    let h = reactor::spawn_server_epoll(map(12), 1).unwrap();
    let mut c = Client::connect(h.addr()).unwrap();
    c.send_raw(b"B 2\nP 5 50\nG 5\n").unwrap();
    assert_eq!(c.read_reply_line().unwrap(), "- 50");
    h.shutdown();

    let d = snapshot().diff(&before);
    assert!(d.counter("frames_decoded") >= 1, "no frames counted");
    let bs = d.hist("batch_size").unwrap();
    assert!(bs.count() >= 1, "batch size not recorded");
    assert!(bs.buckets[1] >= 1, "the 2-op batch belongs in bucket [2,4)");
    assert!(d.counter("bytes_in_epoll") > 0, "request bytes not counted");
    assert!(d.counter("bytes_out_epoll") > 0, "reply bytes not counted");
}
