//! Integration tier for the `crh lint` static-analysis pass
//! (`src/analysis`): proves each rule L001–L005 fires on the
//! deliberately violating fixtures under `tests/lint_fixtures/`
//! (which the default tree walk skips — they are linted only
//! explicitly, here), and that the crate's own tree is lint-clean —
//! the same self-audit CI enforces as a blocking `crh lint` lane.

use std::path::{Path, PathBuf};

use crh::analysis::{collect_rs_files, lint_paths, lint_sources, Diag};

fn fixture(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(rel)
}

fn lint_fixture(rel: &str) -> Vec<Diag> {
    lint_paths(&[fixture(rel)]).expect("fixture path lints")
}

#[test]
fn l001_undocumented_unsafe_fires() {
    let diags = lint_fixture("l001.rs");
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!((diags[0].rule, diags[0].line), ("L001", 12));
    assert!(diags[0].msg.contains("SAFETY"), "{}", diags[0].msg);
}

#[test]
fn l002_undocumented_relaxed_fires_outside_tests() {
    // The on-disk copy sits under `tests/`, which L002 exempts
    // wholesale — the whole fixture must stay quiet there.
    assert!(lint_fixture("l002.rs").is_empty());

    // The same bytes in crate-source position fire exactly once: the
    // documented site and the `#[cfg(test)]` site are exempt, the
    // bare `Ordering::Relaxed` load is not.
    let src = std::fs::read_to_string(fixture("l002.rs")).unwrap();
    let diags = lint_sources(&[(Path::new("src/fixture.rs"), src.as_str())]);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!((diags[0].rule, diags[0].line), ("L002", 17));
    assert!(diags[0].msg.contains("ORDERING"), "{}", diags[0].msg);
}

#[test]
fn l003_unjustified_allow_fires() {
    let diags = lint_fixture("l003.rs");
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!((diags[0].rule, diags[0].line), ("L003", 10));
    assert!(diags[0].msg.contains("justification"), "{}", diags[0].msg);
}

#[test]
fn l004_duplicate_declaration_and_typo_lookup_fire() {
    // Diagnostics come back sorted by path: the caller's typo'd
    // lookup first, then the registry's duplicate declaration.
    let diags = lint_fixture("l004");
    assert_eq!(diags.len(), 2, "{diags:?}");
    assert_eq!((diags[0].rule, diags[0].line), ("L004", 7));
    assert!(diags[0].msg.contains("not declared"), "{}", diags[0].msg);
    assert!(diags[0].msg.contains("ops_totle"), "{}", diags[0].msg);
    assert_eq!((diags[1].rule, diags[1].line), ("L004", 9));
    assert!(diags[1].msg.contains("more than once"), "{}", diags[1].msg);
}

#[test]
fn l005_missing_backend_dispatch_fires() {
    let diags = lint_fixture("l005");
    assert_eq!(diags.len(), 1, "{diags:?}");
    // Anchored on the variant's declaration in the codec file, naming
    // the backend that fails to dispatch it.
    assert_eq!((diags[0].rule, diags[0].line), ("L005", 10));
    assert!(diags[0].path.ends_with("service/frame.rs"), "{diags:?}");
    assert!(diags[0].msg.contains("`Stop`"), "{}", diags[0].msg);
    assert!(diags[0].msg.contains("service/uring.rs"), "{}", diags[0].msg);
}

#[test]
fn l005_missing_txn_dispatch_fires() {
    // A backend that never learned the `T <n>` transaction frame
    // hides behind its catch-all arm; L005 names the gap.
    let diags = lint_fixture("l005_txn");
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!((diags[0].rule, diags[0].line), ("L005", 11));
    assert!(diags[0].path.ends_with("service/frame.rs"), "{diags:?}");
    assert!(diags[0].msg.contains("`Txn`"), "{}", diags[0].msg);
    assert!(diags[0].msg.contains("service/reactor.rs"), "{}", diags[0].msg);
}

#[test]
fn default_walk_skips_the_fixture_tree() {
    let tests_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests");
    let files = collect_rs_files(&[tests_dir]).unwrap();
    assert!(files.iter().any(|f| f.ends_with("lint.rs")));
    assert!(
        !files.iter().any(|f| f
            .components()
            .any(|c| c.as_os_str() == "lint_fixtures")),
        "{files:?}"
    );
}

#[test]
fn crate_tree_is_lint_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let paths: Vec<PathBuf> = ["src", "tests", "benches", "../examples"]
        .iter()
        .map(|p| root.join(p))
        .filter(|p| p.is_dir())
        .collect();
    assert!(!paths.is_empty());
    let diags = lint_paths(&paths).unwrap();
    let listing: String =
        diags.iter().map(|d| format!("\n  {d}")).collect();
    assert!(
        diags.is_empty(),
        "crate tree has {} lint diagnostic(s):{listing}",
        diags.len()
    );
}
