//! Grow-boundary tier for the two-generation incremental resize
//! engines (`inc-resize-rh`, `inc-resize-rh-map`, and their sharded
//! compositions): oracle equivalence across forced migrations, churn
//! *during* a migration (the non-blocking claim: operations keep
//! completing while a migration is in flight), pair integrity for the
//! map, and the double-grow regression for the quiescing baseline.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crh::maps::resizable::{IncResizableRobinHood, ResizableRobinHoodMap};
use crh::maps::sharded::Sharded;
use crh::maps::{ConcurrentMap, ConcurrentSet, MapKind, TableKind};
use crh::util::prop;
use crh::util::rng::Rng;

/// Single-threaded oracle drive across several forced migrations: an
/// add-biased mix on a tiny table with a low threshold, checked op by
/// op against `HashSet`, plus a full membership sweep and a
/// grown-capacity assertion at the end.
fn set_grow_boundary_oracle(build: impl Fn() -> Box<dyn ConcurrentSet>) {
    prop::check(
        "incremental resize matches HashSet across grow boundaries",
        8,
        |r: &mut Rng| {
            (0..4000)
                .map(|_| (r.below(10) as u8, 1 + r.below(700)))
                .collect::<Vec<(u8, u64)>>()
        },
        |seq| {
            let t = build();
            let initial_capacity = t.capacity();
            let mut oracle = HashSet::new();
            for &(op, key) in seq {
                // 60% add / 20% remove / 20% contains: net growth.
                let (got, want) = match op {
                    0..=5 => (t.add(key), oracle.insert(key)),
                    6..=7 => (t.remove(key), oracle.remove(&key)),
                    _ => (t.contains(key), oracle.contains(&key)),
                };
                if got != want {
                    return Err(format!(
                        "op {op} key {key}: got {got} want {want}"
                    ));
                }
            }
            if t.len_quiesced() != oracle.len() {
                return Err(format!(
                    "len {} vs oracle {}",
                    t.len_quiesced(),
                    oracle.len()
                ));
            }
            for k in 1..=700u64 {
                if t.contains(k) != oracle.contains(&k) {
                    return Err(format!("membership mismatch at {k}"));
                }
            }
            if oracle.len() > 230 && t.capacity() == initial_capacity {
                return Err("no migration ran across the boundary".into());
            }
            Ok(())
        },
    );
}

#[test]
fn inc_set_oracle_across_grow_boundary() {
    set_grow_boundary_oracle(|| {
        Box::new(IncResizableRobinHood::with_threshold(8, 0.7))
    });
}

#[test]
fn sharded_inc_set_oracle_across_grow_boundary() {
    set_grow_boundary_oracle(|| {
        Box::new(Sharded::<IncResizableRobinHood>::inc_resizable_with_threshold(
            8, 2, 0.7,
        ))
    });
}

#[test]
fn inc_map_oracle_across_grow_boundary() {
    map_grow_boundary_oracle(|| {
        Box::new(ResizableRobinHoodMap::with_threshold(8, 0.7))
    });
}

#[test]
fn sharded_inc_map_oracle_across_grow_boundary() {
    map_grow_boundary_oracle(|| {
        Box::new(
            Sharded::<ResizableRobinHoodMap>::inc_resizable_map_with_threshold(
                8, 2, 0.7,
            ),
        )
    });
}

/// Map twin of the set oracle: overwrite semantics (`insert` returns
/// the previous value) must survive migrations too.
fn map_grow_boundary_oracle(build: impl Fn() -> Box<dyn ConcurrentMap>) {
    prop::check(
        "incremental resize map matches HashMap across grow boundaries",
        8,
        |r: &mut Rng| {
            (0..4000)
                .map(|_| (r.below(10) as u8, 1 + r.below(700), r.below(1000)))
                .collect::<Vec<(u8, u64, u64)>>()
        },
        |seq| {
            let m = build();
            let initial_capacity = m.capacity();
            let mut oracle: HashMap<u64, u64> = HashMap::new();
            for &(op, key, val) in seq {
                let (got, want) = match op {
                    0..=5 => (m.insert(key, val), oracle.insert(key, val)),
                    6..=7 => (m.remove(key), oracle.remove(&key)),
                    _ => (m.get(key), oracle.get(&key).copied()),
                };
                if got != want {
                    return Err(format!(
                        "op {op} key {key} val {val}: got {got:?} want {want:?}"
                    ));
                }
            }
            if m.len_quiesced() != oracle.len() {
                return Err(format!(
                    "len {} vs oracle {}",
                    m.len_quiesced(),
                    oracle.len()
                ));
            }
            for k in 1..=700u64 {
                if m.get(k) != oracle.get(&k).copied() {
                    return Err(format!("pairing mismatch at {k}"));
                }
            }
            m.check_invariant_quiesced()?;
            if oracle.len() > 230 && m.capacity() == initial_capacity {
                return Err("no migration ran across the boundary".into());
            }
            Ok(())
        },
    );
}

/// The non-blocking claim, witnessed structurally: threads hammer the
/// table across several forced migrations and count the operations
/// that completed **while a migration was in flight**. With the
/// quiescing engine that count is (near) zero — every op blocks on the
/// epoch lock for the whole rebuild; the incremental engine must keep
/// serving. Afterwards the table must agree with itself (every key it
/// reports holding is findable) and must actually have grown.
#[test]
fn churn_keeps_completing_during_migration() {
    let t = Arc::new(IncResizableRobinHood::with_threshold(9, 0.7));
    let during = Arc::new(AtomicU64::new(0));
    let mut hs = Vec::new();
    for tid in 0..6u64 {
        let t = t.clone();
        let during = during.clone();
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0xF15, tid);
            for i in 0..20_000u64 {
                // Add-biased over a wide key range: drives several
                // migrations while the loop runs.
                let k = 1 + r.below(6000);
                match i % 4 {
                    0 | 1 => {
                        t.add(k);
                    }
                    2 => {
                        t.contains(k);
                    }
                    _ => {
                        t.remove(k);
                    }
                }
                if t.migration_active() {
                    during.fetch_add(1, Ordering::Relaxed);
                }
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    t.finish_migration();
    assert!(t.generations() > 1, "no migration ever ran");
    assert!(t.capacity() > 512, "capacity {}", t.capacity());
    assert!(
        during.load(Ordering::Relaxed) > 0,
        "no op completed during a migration — resize is blocking"
    );
    t.check_invariant().unwrap();
    // Self-agreement after settling: every held key is findable.
    let mut present = 0;
    for k in 1..=6000u64 {
        if t.contains(k) {
            present += 1;
        }
    }
    assert_eq!(present, t.len_quiesced());
}

/// Map churn across migrations with the pair invariant (value always
/// encodes its key): a get must never observe a torn pair, even while
/// pairs are being transferred between generations.
#[test]
fn map_pairs_never_tear_across_migration() {
    let m = Arc::new(ResizableRobinHoodMap::with_threshold(8, 0.7));
    let mut hs = Vec::new();
    for tid in 0..3u64 {
        let m = m.clone();
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0xF16, tid);
            for _ in 0..prop::scaled(15_000) {
                let k = 1 + r.below(1500);
                m.insert(k, k * 7);
                if r.below(4) == 0 {
                    m.remove(k);
                }
            }
        }));
    }
    for tid in 0..3u64 {
        let m = m.clone();
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0xF17, tid);
            for _ in 0..prop::scaled(30_000) {
                let k = 1 + r.below(1500);
                if let Some(v) = m.get(k) {
                    assert_eq!(v, k * 7, "torn pair across migration: {k}");
                }
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    m.finish_migration();
    assert!(m.capacity() > 256, "no migration ran");
    m.check_invariant_quiesced().unwrap();
}

/// Fig. 5-style race across migrations: stable keys must never be
/// reported absent while churn forces generation transfers around them
/// (transfers relocate keys just like backward shifts do — the
/// old→new fall-through must be airtight).
#[test]
fn stable_keys_survive_migrations() {
    let t = Arc::new(IncResizableRobinHood::with_threshold(8, 0.6));
    const STABLE: u64 = 40;
    for k in 1..=STABLE {
        assert!(t.add(k));
    }
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut hs = Vec::new();
    // Churners force repeated growth with fresh keys, then clear out.
    for tid in 0..3u64 {
        let (t, stop) = (t.clone(), stop.clone());
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0xF18, tid);
            let mut next = 10_000 + tid * 1_000_000;
            while !stop.load(Ordering::Relaxed) {
                for _ in 0..64 {
                    t.add(next);
                    next += 1;
                }
                for _ in 0..48 {
                    let k = 10_000 + tid * 1_000_000 + r.below(next - 10_000);
                    t.remove(k);
                }
            }
        }));
    }
    for tid in 0..4u64 {
        let (t, stop) = (t.clone(), stop.clone());
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0xF19, tid);
            for _ in 0..prop::scaled(30_000) {
                let k = 1 + r.below(STABLE);
                assert!(
                    t.contains(k),
                    "stable key {k} lost across a migration"
                );
            }
            stop.store(true, Ordering::Relaxed);
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    t.finish_migration();
    assert!(t.generations() > 1, "churn never forced a migration");
    t.check_invariant().unwrap();
}

/// Read-your-writes through growth for the sharded incremental
/// composition (the spec string the service layer will use).
#[test]
fn sharded_inc_read_your_writes_through_growth() {
    let t: Arc<dyn ConcurrentSet> =
        Arc::from(TableKind::parse("inc-resize-rh:4").unwrap().build(9));
    let mut hs = Vec::new();
    for tid in 0..6u64 {
        let t = t.clone();
        hs.push(std::thread::spawn(move || {
            let base = 1 + tid * 10_000;
            for k in base..base + 500 {
                assert!(t.add(k));
                assert!(t.contains(k), "read-your-write across grow");
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    assert_eq!(t.len_quiesced(), 3000);
}

/// Map kind spec round-trips through the service-layer builder and
/// actually grows (the map layer had no growable table before).
#[test]
fn inc_map_kind_grows_through_builder() {
    let m = MapKind::parse("inc-resize-rh-map").unwrap().build(6);
    for k in 1..=200u64 {
        assert_eq!(m.insert(k, k + 9), None, "insert {k}");
    }
    assert!(m.capacity() >= 256, "capacity {}", m.capacity());
    for k in 1..=200u64 {
        assert_eq!(m.get(k), Some(k + 9));
    }
    assert_eq!(m.len_quiesced(), 200);
    m.check_invariant_quiesced().unwrap();
}

/// Single-threaded RMW oracle across forced migrations: the
/// conditional surface (`compare_exchange` corners, `get_or_insert`,
/// `fetch_add`) driven through several grow boundaries, checked op by
/// op against `HashMap` reference semantics.
#[test]
fn rmw_oracle_across_grow_boundary() {
    prop::check(
        "conditional ops match HashMap across grow boundaries",
        8,
        |r: &mut Rng| {
            (0..3000)
                .map(|_| (r.below(8) as u8, 1 + r.below(500), r.below(6)))
                .collect::<Vec<(u8, u64, u64)>>()
        },
        |seq| {
            let m = ResizableRobinHoodMap::with_threshold(7, 0.7);
            let initial_capacity = ConcurrentMap::capacity(&m);
            let mut oracle: HashMap<u64, u64> = HashMap::new();
            for &(op, key, a) in seq {
                let (got, want): (String, String) = match op {
                    // Growth-biased: half the mix inserts one way or
                    // another.
                    0 | 1 => (
                        format!("{:?}", m.get_or_insert(key, a)),
                        format!("{:?}", {
                            let cur = oracle.get(&key).copied();
                            if cur.is_none() {
                                oracle.insert(key, a);
                            }
                            cur
                        }),
                    ),
                    2 | 3 => (
                        format!("{:?}", m.fetch_add(key, a)),
                        format!("{:?}", {
                            let cur = oracle.get(&key).copied();
                            oracle.insert(key, cur.unwrap_or(0) + a);
                            cur
                        }),
                    ),
                    4 | 5 => {
                        let e = if op == 4 { None } else { Some(a) };
                        let n = if a == 0 { None } else { Some(a + 1) };
                        (
                            format!("{:?}", m.compare_exchange(key, e, n)),
                            format!("{:?}", {
                                let cur = oracle.get(&key).copied();
                                if cur == e {
                                    match n {
                                        Some(v) => {
                                            oracle.insert(key, v);
                                        }
                                        None => {
                                            oracle.remove(&key);
                                        }
                                    }
                                    Ok::<(), Option<u64>>(())
                                } else {
                                    Err(cur)
                                }
                            }),
                        )
                    }
                    6 => (
                        format!("{:?}", m.remove(key)),
                        format!("{:?}", oracle.remove(&key)),
                    ),
                    _ => (
                        format!("{:?}", m.get(key)),
                        format!("{:?}", oracle.get(&key).copied()),
                    ),
                };
                if got != want {
                    return Err(format!(
                        "op {op} key {key} a {a}: got {got} want {want}"
                    ));
                }
            }
            if m.len_quiesced() != oracle.len() {
                return Err(format!(
                    "len {} vs oracle {}",
                    m.len_quiesced(),
                    oracle.len()
                ));
            }
            for k in 1..=500u64 {
                if m.get(k) != oracle.get(&k).copied() {
                    return Err(format!("sweep mismatch at {k}"));
                }
            }
            if oracle.len() > 120
                && ConcurrentMap::capacity(&m) == initial_capacity
            {
                return Err("no migration ran across the boundary".into());
            }
            m.check_invariant_quiesced().map_err(|e| e.to_string())
        },
    );
}

/// Concurrent counter workload (fetch_add + optimistic cmpex) driven
/// straight through forced migrations, sharded and unsharded: no
/// committed increment may be lost while pairs move between
/// generations — the tentpole's atomicity claim under resize.
fn rmw_totals_across_migration_on(name: &str, m: Arc<dyn ConcurrentMap>) {
    let initial_capacity = m.capacity();
    const KEYS: u64 = 8;
    const THREADS: u64 = 4;
    let mut hs = Vec::new();
    for tid in 0..THREADS {
        let m = m.clone();
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0xF00D, tid);
            let mut incs = 0u64;
            // Filler inserts force migrations while the counters
            // churn; filler keys stay out of the counter range.
            for round in 0..6_000u64 {
                if round % 8 == 0 {
                    let filler = 1_000 + tid * 100_000 + round;
                    m.insert(filler, filler);
                }
                let k = 1 + r.below(KEYS);
                if r.below(3) == 0 {
                    let cur = m.get(k);
                    let next = cur.unwrap_or(0) + 1;
                    if m.compare_exchange(k, cur, Some(next)).is_ok() {
                        incs += 1;
                    }
                } else {
                    m.fetch_add(k, 1);
                    incs += 1;
                }
            }
            incs
        }));
    }
    let total: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
    let sum: u64 = (1..=KEYS).map(|k| m.get(k).unwrap_or(0)).sum();
    assert_eq!(sum, total, "{name}: increments lost across migration");
    // Drains any still-in-flight migration before the capacity look.
    m.check_invariant_quiesced().unwrap();
    assert!(
        m.capacity() > initial_capacity,
        "{name}: no migration ran (capacity stuck at {initial_capacity})"
    );
}

#[test]
fn concurrent_rmw_totals_across_migration() {
    rmw_totals_across_migration_on(
        "inc-resize-rh-map",
        Arc::new(ResizableRobinHoodMap::with_threshold(7, 0.6)),
    );
    rmw_totals_across_migration_on(
        "sharded inc-resize-rh-map x4",
        Arc::new(
            Sharded::<ResizableRobinHoodMap>::inc_resizable_map_with_threshold(
                9, 2, 0.6,
            ),
        ),
    );
}
