//! Front-end tier tests: the epoll reactor, the io_uring backend, and
//! the thread-per-conn server against adversarial framing (frames
//! split across `read()` boundaries, oversized `B <n>` counts,
//! trailing garbage), a slow-reader client driving the backpressure
//! machinery, reply-transcript equivalence across the three backends,
//! the kernel-too-old fallback path, `SO_REUSEPORT` multi-listener
//! accepting, and the shutdown handles actually joining every thread
//! they spawned.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use crh::maps::{ConcurrentMap, MapKind};
use crh::service::server::{self, Client};
use crh::service::{reactor, uring, FrontendHandle};

fn map(size_log2: u32) -> Arc<dyn ConcurrentMap> {
    Arc::from(MapKind::ShardedKCasRhMap { shards: 4 }.build(size_log2))
}

/// The fixed-trace equivalence gate that the `fig17_frontend --quick`
/// CI step also runs: all three backends must answer the full protocol
/// trace (every verb, every ERR class, batch frames, fragmented
/// writes) byte-identically.
#[test]
fn backends_answer_fixed_trace_identically() {
    let lines = crh::coordinator::fig17_equivalence(12);
    assert!(lines > 0);
}

/// Frames fragmented to one byte per `write()` must decode exactly as
/// coalesced ones — including a batch frame whose header and body
/// straddle fragments, an oversized batch count, and trailing garbage
/// between valid frames.
fn check_reassembles_fragmented_frames(h: FrontendHandle) {
    let mut c = Client::connect(h.addr()).unwrap();
    let blob = "P 4 44\nB 2\nG 4\nA 4 6\nB 9999\nG 4 junk\nG 4\n";
    for byte in blob.as_bytes() {
        c.send_raw(std::slice::from_ref(byte)).unwrap();
    }
    assert_eq!(c.read_reply_line().unwrap(), "-");
    assert_eq!(c.read_reply_line().unwrap(), "44 44");
    assert_eq!(c.read_reply_line().unwrap(), "ERR bad batch size");
    assert_eq!(c.read_reply_line().unwrap(), "ERR bad request");
    assert_eq!(c.read_reply_line().unwrap(), "50");
    h.shutdown();
}

#[test]
fn reactor_reassembles_fragmented_frames() {
    check_reassembles_fragmented_frames(FrontendHandle::Reactor(
        reactor::spawn_server_epoll(map(12), 2).unwrap(),
    ));
}

#[test]
fn uring_reassembles_fragmented_frames() {
    check_reassembles_fragmented_frames(FrontendHandle::Uring(
        uring::spawn_server_uring(map(12), 2).unwrap(),
    ));
}

/// A batch body split across many writes, with the connection still
/// serving afterwards when a member op is invalid (frame rejected as a
/// unit, stream stays in sync).
fn check_batch_member_validation_across_fragments(h: FrontendHandle) {
    let mut c = Client::connect(h.addr()).unwrap();
    let blob = "B 3\nP 6 60\nG 0\nP 6 61\nG 6\n";
    for chunk in blob.as_bytes().chunks(3) {
        c.send_raw(chunk).unwrap();
    }
    assert_eq!(c.read_reply_line().unwrap(), "ERR key out of range");
    assert_eq!(c.read_reply_line().unwrap(), "-", "bad batch was applied");
    h.shutdown();
}

#[test]
fn reactor_batch_member_validation_across_fragments() {
    check_batch_member_validation_across_fragments(FrontendHandle::Reactor(
        reactor::spawn_server_epoll(map(12), 1).unwrap(),
    ));
}

#[test]
fn uring_batch_member_validation_across_fragments() {
    check_batch_member_validation_across_fragments(FrontendHandle::Uring(
        uring::spawn_server_uring(map(12), 1).unwrap(),
    ));
}

/// A client that floods requests while refusing to read replies: the
/// reply backlog must back up through the backend's high-water pause
/// and low-water resume without losing, duplicating, or reordering a
/// single reply. Tiny kernel socket buffers force the backlog into
/// the server's user-space buffer rather than the kernel's.
fn check_slow_reader_backpressure(h: FrontendHandle) {
    // Scaled down under the sanitizer lane (CRH_TEST_SCALE_DIV): the
    // instrumented run still crosses every pause/flush/replay edge,
    // just with a smaller backlog.
    let adds: u64 = crh::util::prop::scaled(100_000);
    const BASE: u64 = 4_000_000_000_000_000_000;

    let stream = TcpStream::connect(h.addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    #[cfg(target_os = "linux")]
    {
        use std::os::fd::AsRawFd;
        // ~16 KiB effective each way: replies can't hide in the kernel.
        crh::util::sys::set_recv_buffer(stream.as_raw_fd(), 8192).unwrap();
        crh::util::sys::set_send_buffer(stream.as_raw_fd(), 8192).unwrap();
    }
    let mut write_half = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();

    // Seed the counter so every reply is a fat 19-digit value.
    write_half.write_all(format!("P 7 {BASE}\n").as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "-");

    // Writer thread floods fetch-adds; the main thread deliberately
    // sleeps before reading a single reply, so ~2 MB of replies must
    // queue against the high-water mark.
    let writer = std::thread::spawn(move || {
        let chunk = "A 7 1\n".repeat(512);
        let mut sent = 0u64;
        while sent < adds {
            let n = (adds - sent).min(512);
            let bytes = &chunk.as_bytes()[..n as usize * 6];
            write_half.write_all(bytes).expect("flood write");
            sent += n;
        }
    });
    std::thread::sleep(Duration::from_millis(300));

    for i in 0..adds {
        line.clear();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "connection died at reply {i}"
        );
        let got: u64 = line.trim_end().parse().unwrap_or_else(|_| {
            panic!("reply {i} not a value: {:?}", line.trim_end())
        });
        assert_eq!(got, BASE + i, "reply out of order at {i}");
    }
    writer.join().unwrap();
    h.shutdown();
}

#[test]
fn reactor_slow_reader_backpressure_keeps_reply_order() {
    check_slow_reader_backpressure(FrontendHandle::Reactor(
        reactor::spawn_server_epoll(map(14), 2).unwrap(),
    ));
}

#[test]
fn uring_slow_reader_backpressure_keeps_reply_order() {
    check_slow_reader_backpressure(FrontendHandle::Uring(
        uring::spawn_server_uring(map(14), 2).unwrap(),
    ));
}

/// The threaded server's shutdown handle joins the accept loop *and*
/// every connection thread, even with live mid-conversation clients —
/// the `spawn_server` leak fix.
#[test]
fn threaded_shutdown_joins_with_live_connections() {
    let h = server::spawn_server(map(12)).unwrap();
    let addr = h.addr();
    let mut clients: Vec<Client> = (1..=3u64)
        .map(|k| {
            let mut c = Client::connect(addr).unwrap();
            assert_eq!(c.request_line(&format!("P {k} {k}")).unwrap(), "-");
            c
        })
        .collect();
    // shutdown() returning proves every thread was joined (a stranded
    // reader would leave accept_loop blocked forever).
    h.shutdown();
    // The live connections were closed under the clients.
    for c in clients.iter_mut() {
        assert!(c.request_line("G 1").is_err());
    }
}

/// Same property for the reactor handle, plus: the listener is gone.
#[test]
fn reactor_shutdown_joins_and_closes_listener() {
    let h = reactor::spawn_server_epoll(map(12), 3).unwrap();
    let addr = h.addr();
    let mut c = Client::connect(addr).unwrap();
    assert_eq!(c.request_line("P 2 2").unwrap(), "-");
    h.shutdown();
    assert!(c.request_line("G 2").is_err(), "connection survived shutdown");
    // The port no longer accepts (tolerate the astronomically unlikely
    // immediate reuse by a foreign process: a successful connect must
    // then fail to serve our protocol).
    match Client::connect(addr) {
        Err(_) => {}
        Ok(mut c2) => assert!(c2.request_line("G 2").is_err()),
    }
}

/// Same property for the uring handle, with live mid-conversation
/// clients across multiple ring workers (or the epoll fallback on
/// kernels without io_uring — the contract is identical).
#[test]
fn uring_shutdown_joins_and_closes_listener() {
    let h = uring::spawn_server_uring(map(12), 3).unwrap();
    let addr = h.addr();
    let mut clients: Vec<Client> = (1..=3u64)
        .map(|k| {
            let mut c = Client::connect(addr).unwrap();
            assert_eq!(c.request_line(&format!("P {k} {k}")).unwrap(), "-");
            c
        })
        .collect();
    h.shutdown();
    for c in clients.iter_mut() {
        assert!(c.request_line("G 1").is_err());
    }
    match Client::connect(addr) {
        Err(_) => {}
        Ok(mut c2) => assert!(c2.request_line("G 2").is_err()),
    }
}

/// The kernel-too-old path: with the fallback forced (the programmatic
/// stand-in for `io_uring_setup` returning `ENOSYS` — mutating
/// process-global env from a multithreaded test binary is the
/// setenv/getenv race the TSan lane exists to catch, so a hook is used
/// instead), the uring spawn must cleanly serve through the epoll
/// reactor behind the same handle API, and report that it did.
///
/// The hook is process-global, so other uring tests running
/// concurrently may transiently spawn in fallback mode too — they
/// assert protocol behaviour, which is identical by construction, not
/// ring mode.
#[test]
fn uring_kernel_too_old_falls_back_to_epoll() {
    uring::force_fallback(true);
    assert!(
        !uring::uring_frontend_available(),
        "forced fallback must gate availability"
    );
    let h = uring::spawn_server_uring(map(12), 2).unwrap();
    assert!(h.is_fallback(), "forced fallback must take the epoll path");
    let mut c = Client::connect(h.addr()).unwrap();
    assert_eq!(c.request_line("P 3 33").unwrap(), "-");
    assert_eq!(c.request_line("A 3 2").unwrap(), "33");
    assert_eq!(c.request_line("G 3").unwrap(), "35");
    h.shutdown();
    uring::force_fallback(false);
}

/// The reactor's `SO_REUSEPORT` multi-listener mode: every worker
/// accepts on its own listener bound to one shared port; connections
/// land on different workers but serve one map.
#[cfg(target_os = "linux")]
#[test]
fn reactor_reuseport_listeners_share_one_port() {
    let m = map(12);
    let h = reactor::serve_epoll_reuseport(
        std::net::SocketAddr::from(([127, 0, 0, 1], 0)),
        m.clone(),
        3,
    )
    .unwrap();
    let addr = h.addr();
    let handles: Vec<_> = (0..12u64)
        .map(|tid| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let k = 1 + tid;
                assert_eq!(
                    c.request_line(&format!("P {k} {}", k * 10)).unwrap(),
                    "-"
                );
                assert_eq!(
                    c.request_line(&format!("G {k}")).unwrap(),
                    (k * 10).to_string()
                );
            })
        })
        .collect();
    for th in handles {
        th.join().unwrap();
    }
    assert_eq!(m.len_quiesced(), 12);
    h.shutdown();
}

/// The CRH_TEST_SCALE_DIV knob the sanitizer CI lane uses to shrink
/// the stress tiers' iteration counts. Tested through the pure
/// [`crh::util::prop::scaled_by`] rule — mutating process-global env
/// from a multi-threaded test binary is exactly the setenv/getenv
/// race the TSan lane exists to catch.
#[test]
fn test_scale_knob_divides_iterations() {
    use crh::util::prop::{scale_div, scaled, scaled_by};
    assert_eq!(scaled_by(1000, 1), 1000);
    assert_eq!(scaled_by(1000, 20), 50);
    assert_eq!(scaled_by(5, 20), 1, "never scales to zero");
    assert_eq!(scaled_by(1000, 0), 1000, "divisor floored at 1");
    // The env-reading path composes the same rule with whatever the
    // harness set (possibly nothing).
    assert_eq!(scaled(1000), scaled_by(1000, scale_div()));
}
