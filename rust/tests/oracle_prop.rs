//! Cross-table property tests: every table must behave exactly like
//! `std::collections::HashSet` under arbitrary op sequences (the
//! single-threaded linearizable oracle), with longer sequences and more
//! keys than the per-module unit tests.

use std::collections::HashSet;

use crh::maps::{ConcurrentSet, TableKind};
use crh::util::prop;
use crh::util::rng::Rng;

fn oracle_check(kind: TableKind, size_log2: u32, keys: u64, ops: usize) {
    prop::check(
        &format!("{} matches HashSet", kind.name()),
        15,
        |r: &mut Rng| {
            (0..ops)
                .map(|_| (r.below(3) as u8, 1 + r.below(keys)))
                .collect::<Vec<(u8, u64)>>()
        },
        |seq| {
            let t = kind.build(size_log2);
            let mut oracle = HashSet::new();
            for &(op, key) in seq {
                let (got, want) = match op {
                    0 => (t.add(key), oracle.insert(key)),
                    1 => (t.remove(key), oracle.remove(&key)),
                    _ => (t.contains(key), oracle.contains(&key)),
                };
                if got != want {
                    return Err(format!(
                        "{} op {op} key {key}: got {got} want {want}",
                        kind.name()
                    ));
                }
            }
            if t.len_quiesced() != oracle.len() {
                return Err(format!(
                    "{}: len {} vs oracle {}",
                    kind.name(),
                    t.len_quiesced(),
                    oracle.len()
                ));
            }
            // Post-hoc full membership sweep.
            for k in 1..=keys {
                if t.contains(k) != oracle.contains(&k) {
                    return Err(format!("{}: sweep mismatch at {k}", kind.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn kcas_rh_oracle_long() {
    oracle_check(TableKind::KCasRobinHood, 8, 160, 1200);
}

#[test]
fn tx_rh_oracle_long() {
    oracle_check(TableKind::TxRobinHood, 8, 160, 1200);
}

#[test]
fn hopscotch_oracle_long() {
    oracle_check(TableKind::Hopscotch, 8, 160, 1200);
}

#[test]
fn lockfree_lp_oracle_long() {
    oracle_check(TableKind::LockFreeLp, 8, 160, 1200);
}

#[test]
fn locked_lp_oracle_long() {
    oracle_check(TableKind::LockedLp, 8, 160, 1200);
}

#[test]
fn michael_oracle_long() {
    oracle_check(TableKind::Michael, 8, 160, 1200);
}

#[test]
fn serial_rh_oracle_long() {
    oracle_check(TableKind::SerialRobinHood, 8, 160, 1200);
}

#[test]
fn resizable_rh_oracle_long() {
    oracle_check(TableKind::ResizableRobinHood, 8, 160, 1200);
}

#[test]
fn sharded_kcas_rh_oracle_long() {
    for shards in TableKind::SHARD_SWEEP {
        oracle_check(TableKind::ShardedKCasRh { shards }, 8, 160, 1200);
    }
}

#[test]
fn sharded_resizable_rh_oracle_long() {
    for shards in TableKind::SHARD_SWEEP {
        oracle_check(TableKind::ShardedResizableRh { shards }, 8, 160, 1200);
    }
}

#[test]
fn inc_resize_rh_oracle_long() {
    oracle_check(TableKind::IncResizableRh, 8, 160, 1200);
}

#[test]
fn sharded_inc_resize_rh_oracle_long() {
    for shards in TableKind::SHARD_SWEEP {
        oracle_check(
            TableKind::ShardedIncResizableRh { shards },
            8,
            160,
            1200,
        );
    }
}

/// Drive `Sharded<ResizableRobinHood>` across per-shard grow boundaries
/// against the `HashSet` oracle: 4 shards x 64 buckets with a 70% grow
/// threshold, an add-biased mix over 700 keys, so several shards must
/// migrate mid-sequence. After the (single-threaded, hence quiesced)
/// sequence, `len_quiesced` and full membership must agree with the
/// oracle, and at least one shard must actually have grown.
#[test]
fn sharded_resizable_grow_boundary_matches_oracle() {
    use crh::maps::resizable::ResizableRobinHood;
    use crh::maps::sharded::Sharded;

    prop::check(
        "sharded-resizable across grow boundary matches HashSet",
        8,
        |r: &mut Rng| {
            (0..4000)
                .map(|_| (r.below(10) as u8, 1 + r.below(700)))
                .collect::<Vec<(u8, u64)>>()
        },
        |seq| {
            let t = Sharded::<ResizableRobinHood>::resizable_with_threshold(
                8, 2, 0.7,
            );
            let initial_capacity = t.capacity();
            let mut oracle = HashSet::new();
            for &(op, key) in seq {
                // 60% add / 20% remove / 20% contains: net growth.
                let (got, want) = match op {
                    0..=5 => (t.add(key), oracle.insert(key)),
                    6..=7 => (t.remove(key), oracle.remove(&key)),
                    _ => (t.contains(key), oracle.contains(&key)),
                };
                if got != want {
                    return Err(format!(
                        "op {op} key {key}: got {got} want {want}"
                    ));
                }
            }
            if t.len_quiesced() != oracle.len() {
                return Err(format!(
                    "len {} vs oracle {}",
                    t.len_quiesced(),
                    oracle.len()
                ));
            }
            for k in 1..=700u64 {
                if t.contains(k) != oracle.contains(&k) {
                    return Err(format!("membership mismatch at {k}"));
                }
            }
            // A full-length sequence holds far more than the initial
            // 256 buckets can at the 70% threshold; the facade must
            // have grown at least one shard (shrunk cases may not).
            if oracle.len() > 230 && t.capacity() == initial_capacity {
                return Err("no shard grew across the boundary".into());
            }
            Ok(())
        },
    );
}

#[test]
fn near_full_tables_stay_correct() {
    // Push open-addressing tables to 95% LF.
    for kind in [
        TableKind::KCasRobinHood,
        TableKind::TxRobinHood,
        TableKind::LockFreeLp,
        TableKind::LockedLp,
        TableKind::SerialRobinHood,
    ] {
        let t = kind.build(8);
        let n = (256.0 * 0.95) as u64;
        for k in 1..=n {
            assert!(t.add(k), "{} add {k}", kind.name());
        }
        for k in 1..=n {
            assert!(t.contains(k), "{} lost {k}", kind.name());
        }
        assert!(!t.contains(n + 1), "{}", kind.name());
        for k in 1..=n {
            assert!(t.remove(k), "{} remove {k}", kind.name());
        }
        assert_eq!(t.len_quiesced(), 0, "{}", kind.name());
    }
}

#[test]
fn interleaved_add_remove_alternating_parity() {
    for kind in TableKind::ALL_CONCURRENT {
        let t = kind.build(10);
        for k in 1..=500u64 {
            t.add(k);
            if k % 2 == 0 {
                t.remove(k - 1);
            }
        }
        // Every odd key k is removed when k+1 is added (500 is even, so
        // 499 is removed too); all even keys survive.
        for k in 1..=500u64 {
            assert_eq!(t.contains(k), k % 2 == 0, "{} key {k}", kind.name());
        }
    }
}

#[test]
fn dfb_snapshots_agree_with_membership() {
    for kind in [
        TableKind::KCasRobinHood,
        TableKind::TxRobinHood,
        TableKind::SerialRobinHood,
        TableKind::Hopscotch,
        TableKind::ResizableRobinHood,
        TableKind::IncResizableRh,
        TableKind::ShardedKCasRh { shards: 4 },
        TableKind::ShardedResizableRh { shards: 4 },
        TableKind::ShardedIncResizableRh { shards: 4 },
    ] {
        let t = kind.build(9);
        for k in 1..=300u64 {
            t.add(k);
        }
        let snap = t.dfb_snapshot();
        let occupied = snap.iter().filter(|&&d| d >= 0).count();
        assert_eq!(occupied, t.len_quiesced(), "{}", kind.name());
        // Robin Hood variants: mean DFB must be small at 59% LF.
        if matches!(
            kind,
            TableKind::KCasRobinHood
                | TableKind::TxRobinHood
                | TableKind::SerialRobinHood
                | TableKind::ResizableRobinHood
                | TableKind::IncResizableRh
                | TableKind::ShardedKCasRh { .. }
                | TableKind::ShardedResizableRh { .. }
                | TableKind::ShardedIncResizableRh { .. }
        ) {
            let sum: i64 = snap.iter().filter(|&&d| d >= 0).map(|&d| d as i64).sum();
            let mean = sum as f64 / occupied as f64;
            assert!(mean < 3.0, "{} mean dfb {mean}", kind.name());
        }
    }
}
