//! Cross-table property tests: every table must behave exactly like
//! `std::collections::HashSet` under arbitrary op sequences (the
//! single-threaded linearizable oracle), with longer sequences and more
//! keys than the per-module unit tests.

use std::collections::HashSet;

use crh::maps::{ConcurrentSet, TableKind};
use crh::util::prop;
use crh::util::rng::Rng;

fn oracle_check(kind: TableKind, size_log2: u32, keys: u64, ops: usize) {
    prop::check(
        &format!("{} matches HashSet", kind.name()),
        15,
        |r: &mut Rng| {
            (0..ops)
                .map(|_| (r.below(3) as u8, 1 + r.below(keys)))
                .collect::<Vec<(u8, u64)>>()
        },
        |seq| {
            let t = kind.build(size_log2);
            let mut oracle = HashSet::new();
            for &(op, key) in seq {
                let (got, want) = match op {
                    0 => (t.add(key), oracle.insert(key)),
                    1 => (t.remove(key), oracle.remove(&key)),
                    _ => (t.contains(key), oracle.contains(&key)),
                };
                if got != want {
                    return Err(format!(
                        "{} op {op} key {key}: got {got} want {want}",
                        kind.name()
                    ));
                }
            }
            if t.len_quiesced() != oracle.len() {
                return Err(format!(
                    "{}: len {} vs oracle {}",
                    kind.name(),
                    t.len_quiesced(),
                    oracle.len()
                ));
            }
            // Post-hoc full membership sweep.
            for k in 1..=keys {
                if t.contains(k) != oracle.contains(&k) {
                    return Err(format!("{}: sweep mismatch at {k}", kind.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn kcas_rh_oracle_long() {
    oracle_check(TableKind::KCasRobinHood, 8, 160, 1200);
}

#[test]
fn tx_rh_oracle_long() {
    oracle_check(TableKind::TxRobinHood, 8, 160, 1200);
}

#[test]
fn hopscotch_oracle_long() {
    oracle_check(TableKind::Hopscotch, 8, 160, 1200);
}

#[test]
fn lockfree_lp_oracle_long() {
    oracle_check(TableKind::LockFreeLp, 8, 160, 1200);
}

#[test]
fn locked_lp_oracle_long() {
    oracle_check(TableKind::LockedLp, 8, 160, 1200);
}

#[test]
fn michael_oracle_long() {
    oracle_check(TableKind::Michael, 8, 160, 1200);
}

#[test]
fn serial_rh_oracle_long() {
    oracle_check(TableKind::SerialRobinHood, 8, 160, 1200);
}

#[test]
fn near_full_tables_stay_correct() {
    // Push open-addressing tables to 95% LF.
    for kind in [
        TableKind::KCasRobinHood,
        TableKind::TxRobinHood,
        TableKind::LockFreeLp,
        TableKind::LockedLp,
        TableKind::SerialRobinHood,
    ] {
        let t = kind.build(8);
        let n = (256.0 * 0.95) as u64;
        for k in 1..=n {
            assert!(t.add(k), "{} add {k}", kind.name());
        }
        for k in 1..=n {
            assert!(t.contains(k), "{} lost {k}", kind.name());
        }
        assert!(!t.contains(n + 1), "{}", kind.name());
        for k in 1..=n {
            assert!(t.remove(k), "{} remove {k}", kind.name());
        }
        assert_eq!(t.len_quiesced(), 0, "{}", kind.name());
    }
}

#[test]
fn interleaved_add_remove_alternating_parity() {
    for kind in TableKind::ALL_CONCURRENT {
        let t = kind.build(10);
        for k in 1..=500u64 {
            t.add(k);
            if k % 2 == 0 {
                t.remove(k - 1);
            }
        }
        // Every odd key k is removed when k+1 is added (500 is even, so
        // 499 is removed too); all even keys survive.
        for k in 1..=500u64 {
            assert_eq!(t.contains(k), k % 2 == 0, "{} key {k}", kind.name());
        }
    }
}

#[test]
fn dfb_snapshots_agree_with_membership() {
    for kind in [
        TableKind::KCasRobinHood,
        TableKind::TxRobinHood,
        TableKind::SerialRobinHood,
        TableKind::Hopscotch,
    ] {
        let t = kind.build(9);
        for k in 1..=300u64 {
            t.add(k);
        }
        let snap = t.dfb_snapshot();
        let occupied = snap.iter().filter(|&&d| d >= 0).count();
        assert_eq!(occupied, t.len_quiesced(), "{}", kind.name());
        // Robin Hood variants: mean DFB must be small at 59% LF.
        if matches!(
            kind,
            TableKind::KCasRobinHood
                | TableKind::TxRobinHood
                | TableKind::SerialRobinHood
        ) {
            let sum: i64 = snap.iter().filter(|&&d| d >= 0).map(|&d| d as i64).sum();
            let mean = sum as f64 / occupied as f64;
            assert!(mean < 3.0, "{} mean dfb {mean}", kind.name());
        }
    }
}
