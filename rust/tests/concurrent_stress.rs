//! Concurrency stress tests across all tables: disjoint-key
//! determinism, contended churn with post-quiesce consistency, the
//! paper's Fig. 5 reader/remover race, and K-CAS helping under stalls.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crh::maps::{ConcurrentSet, TableKind};
use crh::util::prop::scaled;
use crh::util::rng::Rng;

/// The sharded facade kinds exercised per shard count ∈ {1, 4, 16}
/// (`TableKind::SHARD_SWEEP`).
fn sharded_kinds() -> Vec<TableKind> {
    TableKind::SHARD_SWEEP
        .iter()
        .flat_map(|&shards| {
            [
                TableKind::ShardedKCasRh { shards },
                TableKind::ShardedResizableRh { shards },
                TableKind::ShardedIncResizableRh { shards },
            ]
        })
        .collect()
}

/// Disjoint key ranges per thread: the final state is exactly
/// predictable for any linearizable set.
fn disjoint_determinism(kind: TableKind) {
    let t: Arc<dyn ConcurrentSet> = Arc::from(kind.build(13));
    let threads = 8u64;
    let per = 400u64;
    let mut hs = Vec::new();
    for tid in 0..threads {
        let t = t.clone();
        hs.push(std::thread::spawn(move || {
            let base = 1 + tid * 10_000;
            for k in base..base + per {
                assert!(t.add(k), "{} add {k}", t.name());
            }
            for k in (base..base + per).step_by(4) {
                assert!(t.remove(k), "{} remove {k}", t.name());
            }
            for k in base..base + per {
                assert_eq!(t.contains(k), (k - base) % 4 != 0, "{}", t.name());
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    assert_eq!(
        t.len_quiesced() as u64,
        threads * (per - per / 4),
        "{}",
        kind.name()
    );
}

#[test]
fn disjoint_determinism_kcas_rh() {
    disjoint_determinism(TableKind::KCasRobinHood);
}

#[test]
fn disjoint_determinism_tx_rh() {
    disjoint_determinism(TableKind::TxRobinHood);
}

#[test]
fn disjoint_determinism_hopscotch() {
    disjoint_determinism(TableKind::Hopscotch);
}

#[test]
fn disjoint_determinism_lockfree_lp() {
    disjoint_determinism(TableKind::LockFreeLp);
}

#[test]
fn disjoint_determinism_locked_lp() {
    disjoint_determinism(TableKind::LockedLp);
}

#[test]
fn disjoint_determinism_michael() {
    disjoint_determinism(TableKind::Michael);
}

#[test]
fn disjoint_determinism_resizable() {
    disjoint_determinism(TableKind::ResizableRobinHood);
}

#[test]
fn disjoint_determinism_inc_resize() {
    disjoint_determinism(TableKind::IncResizableRh);
}

#[test]
fn disjoint_determinism_sharded() {
    for kind in sharded_kinds() {
        disjoint_determinism(kind);
    }
}

/// Contended churn over a small key range; afterwards every key the
/// table claims to hold must be found, and counts must be consistent.
fn contended_churn(kind: TableKind, size_log2: u32, keys: u64) {
    let t: Arc<dyn ConcurrentSet> = Arc::from(kind.build(size_log2));
    let mut hs = Vec::new();
    for tid in 0..8u64 {
        let t = t.clone();
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0xABCD ^ keys, tid);
            for _ in 0..scaled(6000) {
                let k = 1 + r.below(keys);
                match r.below(3) {
                    0 => {
                        t.add(k);
                    }
                    1 => {
                        t.remove(k);
                    }
                    _ => {
                        t.contains(k);
                    }
                }
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    let mut present = 0;
    for k in 1..=keys {
        if t.contains(k) {
            present += 1;
        }
    }
    assert_eq!(present, t.len_quiesced(), "{}", kind.name());
}

#[test]
fn contended_churn_all_tables() {
    for kind in TableKind::ALL_CONCURRENT {
        contended_churn(kind, 9, 200);
    }
}

#[test]
fn contended_churn_sharded() {
    // Bigger table than the flat-table run so the 16-shard split still
    // leaves headroom per shard under the churn's worst case.
    for kind in sharded_kinds() {
        contended_churn(kind, 10, 200);
    }
}

#[test]
fn contended_churn_tight_tables() {
    // High load factor + tiny table = maximal displacement contention.
    for kind in [
        TableKind::KCasRobinHood,
        TableKind::TxRobinHood,
        TableKind::LockFreeLp,
    ] {
        contended_churn(kind, 7, 100);
    }
}

/// The paper's Fig. 5 race for every table with relocation: stable keys
/// must never be reported absent while unrelated keys churn nearby.
fn stable_keys_under_churn(kind: TableKind) {
    stable_keys_under_churn_sized(kind, 8);
}

fn stable_keys_under_churn_sized(kind: TableKind, size_log2: u32) {
    let t: Arc<dyn ConcurrentSet> = Arc::from(kind.build(size_log2));
    const CHURN: u64 = 80;
    const STABLE: u64 = 40;
    for k in 1..=CHURN + STABLE {
        t.add(k);
    }
    let stop = Arc::new(AtomicBool::new(false));
    let mut hs = Vec::new();
    for tid in 0..3u64 {
        let (t, stop) = (t.clone(), stop.clone());
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0x51, tid);
            while !stop.load(Ordering::Relaxed) {
                let k = 1 + r.below(CHURN);
                t.remove(k);
                t.add(k);
            }
        }));
    }
    for tid in 0..4u64 {
        let (t, stop) = (t.clone(), stop.clone());
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0x52, tid);
            for _ in 0..scaled(40_000) {
                let k = CHURN + 1 + r.below(STABLE);
                assert!(
                    t.contains(k),
                    "{}: missed stable key {k} (Fig. 5 race)",
                    t.name()
                );
            }
            stop.store(true, Ordering::Relaxed);
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
}

#[test]
fn fig5_race_kcas_rh() {
    stable_keys_under_churn(TableKind::KCasRobinHood);
}

#[test]
fn fig5_race_tx_rh() {
    stable_keys_under_churn(TableKind::TxRobinHood);
}

#[test]
fn fig5_race_hopscotch() {
    stable_keys_under_churn(TableKind::Hopscotch);
}

#[test]
fn fig5_race_lockfree_lp() {
    stable_keys_under_churn(TableKind::LockFreeLp);
}

#[test]
fn fig5_race_inc_resize() {
    stable_keys_under_churn(TableKind::IncResizableRh);
}

#[test]
fn fig5_race_sharded() {
    // Size 10 keeps every shard of the 16-way split large enough that
    // the churn range cannot saturate a single shard.
    for kind in sharded_kinds() {
        stable_keys_under_churn_sized(kind, 10);
    }
}

/// Mixed reader/writer workload where every thread validates its OWN
/// key's linearizability: after my add(k) returns true and before my
/// remove(k), contains(k) must be true (nobody else touches my keys).
#[test]
fn per_thread_read_your_writes() {
    let kinds: Vec<TableKind> = TableKind::ALL_CONCURRENT
        .into_iter()
        .chain(sharded_kinds())
        .collect();
    for kind in kinds {
        let t: Arc<dyn ConcurrentSet> = Arc::from(kind.build(12));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(0x77, tid);
                let base = 1 + tid * 100_000;
                for round in 0..scaled(500) {
                    let k = base + r.below(200);
                    if t.add(k) {
                        assert!(t.contains(k), "{} RYW round {round}", t.name());
                        assert!(t.remove(k), "{} remove own", t.name());
                    }
                    assert!(!t.contains(k), "{} after remove", t.name());
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }
}

/// K-CAS specific: concurrent multi-word ops move disjoint AND
/// overlapping word sets; totals must balance exactly.
#[test]
fn kcas_transfer_conservation() {
    use crh::kcas::{OpBuilder, Word};
    const ACCOUNTS: usize = 16;
    const TOTAL: u64 = 16_000;
    let words: Arc<Vec<Word>> =
        Arc::new((0..ACCOUNTS).map(|_| Word::new(1000)).collect());
    let mut hs = Vec::new();
    for tid in 0..8u64 {
        let words = words.clone();
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0x88, tid);
            let mut op = OpBuilder::new();
            let mut done = 0;
            while done < scaled(2000) {
                let a = r.below(ACCOUNTS as u64) as usize;
                let b = r.below(ACCOUNTS as u64) as usize;
                if a == b {
                    continue;
                }
                let (va, vb) = (words[a].read(), words[b].read());
                if va == 0 {
                    continue;
                }
                op.clear();
                op.push(&words[a], va, va - 1);
                op.push(&words[b], vb, vb + 1);
                if op.execute() {
                    done += 1;
                }
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    let sum: u64 = words.iter().map(|w| w.read()).sum();
    assert_eq!(sum, TOTAL, "money created or destroyed");
}

/// Readers must help a writer that stalls mid-K-CAS. We can't truly
/// stall a thread deterministically, but a heavily oversubscribed run
/// (4x threads vs cores) forces preemption inside phase 1/2 regularly;
/// the invariant reader from the kcas module-level test is replicated
/// here at nastier settings.
#[test]
fn kcas_helping_under_oversubscription() {
    use crh::kcas::{OpBuilder, Word};
    let words: Arc<Vec<Word>> = Arc::new((0..8).map(|_| Word::new(0)).collect());
    let stop = Arc::new(AtomicBool::new(false));
    let nthreads = 4 * crh::util::affinity::available_cpus().max(2);
    let mut hs = Vec::new();
    for _ in 0..nthreads.min(64) {
        let words = words.clone();
        let stop = stop.clone();
        hs.push(std::thread::spawn(move || {
            let mut op = OpBuilder::new();
            while !stop.load(Ordering::Relaxed) {
                let v = words[0].read();
                op.clear();
                for w in words.iter() {
                    op.push(w, v, v + 1);
                }
                let _ = op.execute();
            }
        }));
    }
    // Reader asserting the all-equal-at-linearization invariant.
    for _ in 0..scaled(200_000) {
        let x = words[0].read();
        let y = words[7].read();
        assert!(y >= x, "torn K-CAS: {y} < {x}");
    }
    stop.store(true, Ordering::Relaxed);
    for h in hs {
        h.join().unwrap();
    }
    let v = words[0].read();
    for w in words.iter() {
        assert_eq!(w.read(), v);
    }
}
