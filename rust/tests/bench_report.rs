//! End-to-end test of the perf-trajectory pipeline: a real (tiny)
//! figure run produces a `BenchReport`, the report writes itself to
//! disk as `BENCH_<fig>.json`, the file parses back, and comparing the
//! run against itself is clean — the same path CI's fig15 smoke step
//! exercises with `CRH_BENCH_JSON=1`.

use crh::bench::report::{compare, read_snapshot, CellClass};
use crh::coordinator::{fig15_resize, table1, ExpOpts};

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("crh-bench-report-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir
}

#[test]
fn fig15_snapshot_round_trips_and_self_compares_clean() {
    let opts = ExpOpts {
        size_log2: 14,
        duration_ms: 30,
        threads: vec![1],
        pin: false,
        reps: 1,
    };
    let report = fig15_resize(&opts, &[0.7]);
    assert_eq!(report.fig, "fig15");
    // One cell per (grow_at, threads, engine): 1 x 1 x 2.
    assert_eq!(report.cells.len(), 2);
    for cell in &report.cells {
        let ops = cell.ops_per_us.expect("fig15 cells record throughput");
        assert!(ops.median > 0.0, "cell {} measured nothing", cell.id());
        assert_eq!(ops.reps, 1);
        let lat = cell.latency.expect("fig15 cells record latency");
        assert!(lat.p50_ns > 0);
        assert!(lat.p50_ns <= lat.p99_ns && lat.p99_ns <= lat.max_ns);
    }

    let dir = temp_dir("fig15");
    let path = report.write_to(&dir).expect("write snapshot");
    assert!(path.ends_with("BENCH_fig15.json"));
    let back = read_snapshot(&path).expect("snapshot parses back");
    std::fs::remove_dir_all(&dir).ok();

    assert_eq!(back.fig, report.fig);
    assert_eq!(back.cells.len(), report.cells.len());
    let cmp = compare(&report, &back);
    assert!(!cmp.has_regressions(), "self-compare regressed:\n{}", cmp.render());
    assert!(cmp.fingerprint_diffs.is_empty());
    assert_eq!(cmp.count(CellClass::Ok), report.cells.len());
}

#[test]
fn table1_snapshot_is_deterministic_across_runs() {
    // The cache simulator is seeded and single-threaded, so two runs
    // must produce byte-identical cells (only the timestamp differs).
    let a = table1(12, 20_000);
    let b = table1(12, 20_000);
    assert_eq!(a.fig, "table1");
    assert!(!a.cells.is_empty());
    assert_eq!(a.cells.len(), b.cells.len());
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.id(), cb.id());
        assert_eq!(ca.extra, cb.extra, "cell {} drifted between runs", ca.id());
    }
    let cmp = compare(&a, &b);
    assert!(!cmp.has_regressions());
    assert_eq!(cmp.count(CellClass::Ok), a.cells.len());
}
