//! Mechanical linearizability checking (paper §3.4) of recorded
//! concurrent histories, for every table.
//!
//! Each window records ~24 overlapping ops from 3 threads over a tiny
//! key range (maximum contention) and the checker searches for a valid
//! linearization. Many independent windows are checked per table.

use crh::maps::{ConcurrentMap, ConcurrentSet, MapKind, TableKind};
use crh::util::linearize::{
    is_linearizable, is_map_linearizable, record_history, record_map_history,
};

fn check_table(kind: TableKind, windows: u64) {
    for w in 0..windows {
        let table = kind.build(7);
        // Seed some keys so removes/contains start meaningful.
        let mut initial = Vec::new();
        for k in 1..=4u64 {
            table.add(k);
            initial.push(k);
        }
        let h = record_history(table.as_ref(), 3, 8, 6, 0x11AA + w);
        assert!(
            h.len() == 24,
            "{}: short history {}",
            kind.name(),
            h.len()
        );
        assert!(
            is_linearizable(&initial, &h),
            "{}: non-linearizable history in window {w}: {h:#?}",
            kind.name()
        );
    }
}

#[test]
fn linearizable_kcas_rh() {
    check_table(TableKind::KCasRobinHood, 60);
}

#[test]
fn linearizable_tx_rh() {
    check_table(TableKind::TxRobinHood, 60);
}

#[test]
fn linearizable_hopscotch() {
    check_table(TableKind::Hopscotch, 60);
}

#[test]
fn linearizable_lockfree_lp() {
    check_table(TableKind::LockFreeLp, 60);
}

#[test]
fn linearizable_locked_lp() {
    check_table(TableKind::LockedLp, 60);
}

#[test]
fn linearizable_michael() {
    check_table(TableKind::Michael, 60);
}

#[test]
fn linearizable_resizable_rh() {
    check_table(TableKind::ResizableRobinHood, 60);
}

#[test]
fn linearizable_sharded_kcas_rh() {
    check_table(TableKind::ShardedKCasRh { shards: 4 }, 60);
}

#[test]
fn linearizable_sharded_resizable_rh() {
    check_table(TableKind::ShardedResizableRh { shards: 4 }, 60);
}

#[test]
fn linearizable_inc_resize_rh() {
    check_table(TableKind::IncResizableRh, 60);
}

#[test]
fn linearizable_sharded_inc_resize_rh() {
    check_table(TableKind::ShardedIncResizableRh { shards: 4 }, 60);
}

/// Map windows with the conditional-RMW mix (`compare_exchange`
/// corners, `get_or_insert`, `fetch_add` interleaved with the
/// unconditional trio) over a tiny key range: maximum same-key
/// contention on exactly the ops whose atomicity the tentpole claims.
fn check_map(build: impl Fn() -> Box<dyn ConcurrentMap>, windows: u64, name: &str) {
    for w in 0..windows {
        let m = build();
        let mut initial = Vec::new();
        for k in 1..=3u64 {
            m.insert(k, k);
            initial.push((k, k));
        }
        let h = record_map_history(m.as_ref(), 3, 8, 6, 0x22BB + w);
        assert_eq!(h.len(), 24, "{name}: short history");
        assert!(
            is_map_linearizable(&initial, &h),
            "{name}: non-linearizable RMW history in window {w}: {h:#?}"
        );
    }
}

#[test]
fn linearizable_rmw_kcas_rh_map() {
    check_map(|| MapKind::KCasRhMap.build(7), 60, "kcas-rh-map");
}

#[test]
fn linearizable_rmw_locked_lp_map() {
    check_map(|| MapKind::LockedLpMap.build(7), 60, "locked-lp-map");
}

#[test]
fn linearizable_rmw_sharded_kcas_rh_map_across_shards() {
    for shards in [1u32, 4, 16] {
        check_map(
            || MapKind::ShardedKCasRhMap { shards }.build(8),
            20,
            &format!("sharded-kcas-rh-map:{shards}"),
        );
    }
}

#[test]
fn linearizable_rmw_inc_resize_rh_map() {
    check_map(|| MapKind::IncResizableRhMap.build(7), 40, "inc-resize-rh-map");
}

#[test]
fn linearizable_rmw_during_inc_resize_migration() {
    // Windows recorded while a two-generation migration is in flight:
    // the conditional ops must stay atomic across the freeze/transfer
    // protocol, not just on a settled table.
    use crh::maps::resizable::ResizableRobinHoodMap;
    for w in 0..30u64 {
        // 4096 buckets = 64 migration stripes, so the handful of ops a
        // window records cannot drain the migration before the
        // in-flight assertion below.
        let m = ResizableRobinHoodMap::with_threshold(12, 0.4);
        // Filler keys outside the window range trip the trigger.
        let mut filler = 1000u64;
        while !m.migration_active() {
            m.insert(filler, filler);
            filler += 1;
        }
        let mut initial = Vec::new();
        for k in 1..=3u64 {
            m.insert(k, k);
            initial.push((k, k));
        }
        assert!(
            m.migration_active(),
            "window {w}: migration drained before recording"
        );
        let h = record_map_history(&m, 3, 8, 6, 0x33CC + w);
        assert!(
            is_map_linearizable(&initial, &h),
            "inc-resize-rh-map mid-migration: window {w}: {h:#?}"
        );
    }
}

#[test]
fn checker_catches_a_broken_table() {
    // Sanity: a deliberately broken "set" (contains always false) must
    // be rejected by the checker, proving the harness has teeth.
    struct Broken(crh::maps::serial_rh::SerialRobinHoodLocked);
    impl crh::maps::ConcurrentSet for Broken {
        fn contains(&self, _k: u64) -> bool {
            false // lies
        }
        fn add(&self, k: u64) -> bool {
            self.0.add(k)
        }
        fn remove(&self, k: u64) -> bool {
            self.0.remove(k)
        }
        fn name(&self) -> &'static str {
            "broken"
        }
        fn capacity(&self) -> usize {
            self.0.capacity()
        }
        fn len_quiesced(&self) -> usize {
            self.0.len_quiesced()
        }
    }
    let t = Broken(crh::maps::serial_rh::SerialRobinHoodLocked::new(7));
    let mut initial = Vec::new();
    for k in 1..=4u64 {
        t.add(k);
        initial.push(k);
    }
    let mut any_rejected = false;
    for w in 0..10u64 {
        let h = record_history(&t, 3, 8, 6, 0x77 + w);
        if !is_linearizable(&initial, &h) {
            any_rejected = true;
            break;
        }
    }
    assert!(any_rejected, "checker failed to reject a lying table");
}
