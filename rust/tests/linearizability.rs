//! Mechanical linearizability checking (paper §3.4) of recorded
//! concurrent histories, for every table.
//!
//! Each window records ~24 overlapping ops from 3 threads over a tiny
//! key range (maximum contention) and the checker searches for a valid
//! linearization. Many independent windows are checked per table.

use crh::maps::{ConcurrentSet, TableKind};
use crh::util::linearize::{is_linearizable, record_history};

fn check_table(kind: TableKind, windows: u64) {
    for w in 0..windows {
        let table = kind.build(7);
        // Seed some keys so removes/contains start meaningful.
        let mut initial = Vec::new();
        for k in 1..=4u64 {
            table.add(k);
            initial.push(k);
        }
        let h = record_history(table.as_ref(), 3, 8, 6, 0x11AA + w);
        assert!(
            h.len() == 24,
            "{}: short history {}",
            kind.name(),
            h.len()
        );
        assert!(
            is_linearizable(&initial, &h),
            "{}: non-linearizable history in window {w}: {h:#?}",
            kind.name()
        );
    }
}

#[test]
fn linearizable_kcas_rh() {
    check_table(TableKind::KCasRobinHood, 60);
}

#[test]
fn linearizable_tx_rh() {
    check_table(TableKind::TxRobinHood, 60);
}

#[test]
fn linearizable_hopscotch() {
    check_table(TableKind::Hopscotch, 60);
}

#[test]
fn linearizable_lockfree_lp() {
    check_table(TableKind::LockFreeLp, 60);
}

#[test]
fn linearizable_locked_lp() {
    check_table(TableKind::LockedLp, 60);
}

#[test]
fn linearizable_michael() {
    check_table(TableKind::Michael, 60);
}

#[test]
fn linearizable_resizable_rh() {
    check_table(TableKind::ResizableRobinHood, 60);
}

#[test]
fn linearizable_sharded_kcas_rh() {
    check_table(TableKind::ShardedKCasRh { shards: 4 }, 60);
}

#[test]
fn linearizable_sharded_resizable_rh() {
    check_table(TableKind::ShardedResizableRh { shards: 4 }, 60);
}

#[test]
fn linearizable_inc_resize_rh() {
    check_table(TableKind::IncResizableRh, 60);
}

#[test]
fn linearizable_sharded_inc_resize_rh() {
    check_table(TableKind::ShardedIncResizableRh { shards: 4 }, 60);
}

#[test]
fn checker_catches_a_broken_table() {
    // Sanity: a deliberately broken "set" (contains always false) must
    // be rejected by the checker, proving the harness has teeth.
    struct Broken(crh::maps::serial_rh::SerialRobinHoodLocked);
    impl crh::maps::ConcurrentSet for Broken {
        fn contains(&self, _k: u64) -> bool {
            false // lies
        }
        fn add(&self, k: u64) -> bool {
            self.0.add(k)
        }
        fn remove(&self, k: u64) -> bool {
            self.0.remove(k)
        }
        fn name(&self) -> &'static str {
            "broken"
        }
        fn capacity(&self) -> usize {
            self.0.capacity()
        }
        fn len_quiesced(&self) -> usize {
            self.0.len_quiesced()
        }
    }
    let t = Broken(crh::maps::serial_rh::SerialRobinHoodLocked::new(7));
    let mut initial = Vec::new();
    for k in 1..=4u64 {
        t.add(k);
        initial.push(k);
    }
    let mut any_rejected = false;
    for w in 0..10u64 {
        let h = record_history(&t, 3, 8, 6, 0x77 + w);
        if !is_linearizable(&initial, &h) {
            any_rejected = true;
            break;
        }
    }
    assert!(any_rejected, "checker failed to reject a lying table");
}
