//! Service-layer tier tests: the `ConcurrentMap` implementations
//! against a `std::collections::HashMap` oracle (including the sharded
//! compositions across the {1, 4, 16} shard sweep), the batched API's
//! op-by-op equivalence, the map-flavoured Fig. 5 race, and the TCP
//! request pipeline end-to-end (including the key-range guard that the
//! original one-op-per-line server lacked). Every server test runs
//! against **all three** front-ends — the thread-per-connection
//! pipeline, the epoll event loop, and the io_uring completion-ring
//! backend — since the wire protocol promises they are
//! indistinguishable.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::Arc;

use crh::maps::{ConcurrentMap, MapKind, MapOp, MapReply, MAX_KEY};
use crh::service::batch::apply_batch;
use crh::service::server::Client;
use crh::service::Backend;
use crh::util::prop;
use crh::util::rng::Rng;

/// Run a server test against every front-end — thread-per-connection,
/// epoll reactor, io_uring — fresh map and server per backend,
/// shutdown (joining every spawned thread) afterwards: no stranded
/// accept loops or connection threads survive the test run. On
/// kernels without io_uring the uring backend transparently serves
/// through the epoll reactor, so the tier still covers its
/// spawn/shutdown surface there.
fn with_all_backends(
    build: impl Fn() -> Arc<dyn ConcurrentMap>,
    test: impl Fn(&str, SocketAddr, &Arc<dyn ConcurrentMap>),
) {
    for backend in Backend::ALL {
        let map = build();
        let h = backend
            .spawn(map.clone(), 2)
            .unwrap_or_else(|e| panic!("spawn {backend} server: {e}"));
        test(backend.name(), h.addr(), &map);
        h.shutdown();
    }
}

/// Random op sequences on `kind` must match `HashMap` exactly —
/// including value overwrite on duplicate insert (`insert` returns the
/// previous value) and get-after-remove.
fn map_oracle_check(kind: MapKind, size_log2: u32, keys: u64, ops: usize) {
    prop::check(
        &format!("{} matches HashMap", kind.name()),
        12,
        |r: &mut Rng| {
            (0..ops)
                .map(|_| (r.below(3) as u8, 1 + r.below(keys), r.below(1000)))
                .collect::<Vec<(u8, u64, u64)>>()
        },
        |seq| {
            let m = kind.build(size_log2);
            let mut oracle: HashMap<u64, u64> = HashMap::new();
            for &(op, key, val) in seq {
                let (got, want) = match op {
                    0 => (m.insert(key, val), oracle.insert(key, val)),
                    1 => (m.remove(key), oracle.remove(&key)),
                    _ => (m.get(key), oracle.get(&key).copied()),
                };
                if got != want {
                    return Err(format!(
                        "{} op {op} key {key} val {val}: got {got:?} want {want:?}",
                        kind.name()
                    ));
                }
            }
            if m.len_quiesced() != oracle.len() {
                return Err(format!(
                    "{}: len {} vs oracle {}",
                    kind.name(),
                    m.len_quiesced(),
                    oracle.len()
                ));
            }
            // Post-hoc full pairing sweep.
            for k in 1..=keys {
                if m.get(k) != oracle.get(&k).copied() {
                    return Err(format!("{}: sweep mismatch at {k}", kind.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn kcas_rh_map_oracle_long() {
    map_oracle_check(MapKind::KCasRhMap, 8, 160, 1200);
}

#[test]
fn locked_lp_map_oracle_long() {
    map_oracle_check(MapKind::LockedLpMap, 8, 160, 1200);
}

#[test]
fn sharded_kcas_rh_map_oracle_across_shards() {
    for shards in [1u32, 4, 16] {
        map_oracle_check(MapKind::ShardedKCasRhMap { shards }, 8, 160, 1200);
    }
}

#[test]
fn sharded_locked_lp_map_oracle_across_shards() {
    for shards in [1u32, 4, 16] {
        map_oracle_check(MapKind::ShardedLockedLpMap { shards }, 8, 160, 1200);
    }
}

#[test]
fn inc_resize_rh_map_oracle() {
    map_oracle_check(MapKind::IncResizableRhMap, 8, 160, 1200);
}

#[test]
fn sharded_inc_resize_rh_map_oracle() {
    for shards in crh::maps::TableKind::SHARD_SWEEP {
        map_oracle_check(
            MapKind::ShardedIncResizableRhMap { shards },
            8,
            160,
            1200,
        );
    }
}

/// Random mixed histories over the *conditional-first* surface —
/// `compare_exchange` corners, `get_or_insert`, `fetch_add` interleaved
/// with the unconditional trio — must match a `HashMap` oracle
/// implementing the reference semantics, for every map kind.
fn rmw_oracle_check(kind: MapKind, size_log2: u32, keys: u64, ops: usize) {
    prop::check(
        &format!("{} RMW ops match HashMap", kind.name()),
        10,
        |r: &mut Rng| {
            (0..ops)
                .map(|_| {
                    (
                        r.below(8) as u8,
                        1 + r.below(keys),
                        r.below(6),
                        r.below(6),
                    )
                })
                .collect::<Vec<(u8, u64, u64, u64)>>()
        },
        |seq| {
            let m = kind.build(size_log2);
            let mut oracle: HashMap<u64, u64> = HashMap::new();
            for &(op, key, a, b) in seq {
                // Tiny value domain so conditional hits and witness
                // mismatches both occur constantly.
                let (got, want): (String, String) = match op {
                    0 => (
                        format!("{:?}", m.insert(key, a)),
                        format!("{:?}", oracle.insert(key, a)),
                    ),
                    1 => (
                        format!("{:?}", m.remove(key)),
                        format!("{:?}", oracle.remove(&key)),
                    ),
                    2 => (
                        format!("{:?}", m.get(key)),
                        format!("{:?}", oracle.get(&key).copied()),
                    ),
                    3 => (
                        format!("{:?}", m.get_or_insert(key, a)),
                        format!("{:?}", {
                            let cur = oracle.get(&key).copied();
                            if cur.is_none() {
                                oracle.insert(key, a);
                            }
                            cur
                        }),
                    ),
                    4 => (
                        format!("{:?}", m.fetch_add(key, a)),
                        format!("{:?}", {
                            let cur = oracle.get(&key).copied();
                            oracle.insert(key, cur.unwrap_or(0) + a);
                            cur
                        }),
                    ),
                    _ => {
                        // All four corners occur: expected/new each
                        // drawn independently as absent or a value.
                        let e = if op % 2 == 0 { None } else { Some(a) };
                        let n = if b == 0 { None } else { Some(b) };
                        (
                            format!("{:?}", m.compare_exchange(key, e, n)),
                            format!("{:?}", {
                                let cur = oracle.get(&key).copied();
                                if cur == e {
                                    match n {
                                        Some(v) => {
                                            oracle.insert(key, v);
                                        }
                                        None => {
                                            oracle.remove(&key);
                                        }
                                    }
                                    Ok::<(), Option<u64>>(())
                                } else {
                                    Err(cur)
                                }
                            }),
                        )
                    }
                };
                if got != want {
                    return Err(format!(
                        "{} op {op} key {key} a {a} b {b}: got {got} want {want}",
                        kind.name()
                    ));
                }
            }
            if m.len_quiesced() != oracle.len() {
                return Err(format!(
                    "{}: len {} vs oracle {}",
                    kind.name(),
                    m.len_quiesced(),
                    oracle.len()
                ));
            }
            for k in 1..=keys {
                if m.get(k) != oracle.get(&k).copied() {
                    return Err(format!("{}: sweep mismatch at {k}", kind.name()));
                }
            }
            m.check_invariant_quiesced().map_err(|e| e.to_string())
        },
    );
}

#[test]
fn rmw_oracle_kcas_rh_map() {
    rmw_oracle_check(MapKind::KCasRhMap, 8, 160, 1200);
}

#[test]
fn rmw_oracle_locked_lp_map() {
    rmw_oracle_check(MapKind::LockedLpMap, 8, 160, 1200);
}

#[test]
fn rmw_oracle_sharded_kcas_rh_map_across_shards() {
    for shards in [1u32, 4, 16] {
        rmw_oracle_check(MapKind::ShardedKCasRhMap { shards }, 8, 160, 1200);
    }
}

#[test]
fn rmw_oracle_sharded_locked_lp_map_across_shards() {
    for shards in [1u32, 4, 16] {
        rmw_oracle_check(MapKind::ShardedLockedLpMap { shards }, 8, 160, 1200);
    }
}

#[test]
fn rmw_oracle_inc_resize_rh_map() {
    rmw_oracle_check(MapKind::IncResizableRhMap, 8, 160, 1200);
}

#[test]
fn rmw_oracle_sharded_inc_resize_rh_map() {
    for shards in crh::maps::TableKind::SHARD_SWEEP {
        rmw_oracle_check(
            MapKind::ShardedIncResizableRhMap { shards },
            8,
            160,
            1200,
        );
    }
}

/// Concurrent mixed `compare_exchange`/`fetch_add` histories across the
/// shard sweep: every committed increment (a fetch_add or an optimistic
/// CAS win) is tallied per thread; the counters must sum exactly — on
/// sharded facades the hot keys deliberately straddle shards.
#[test]
fn concurrent_rmw_totals_across_shards() {
    let mut kinds = vec![MapKind::KCasRhMap, MapKind::LockedLpMap];
    for shards in [1u32, 4, 16] {
        kinds.push(MapKind::ShardedKCasRhMap { shards });
    }
    for kind in kinds {
        let m: Arc<dyn ConcurrentMap> = Arc::from(kind.build(10));
        const KEYS: u64 = 6;
        const THREADS: u64 = 6;
        const OPS: u64 = 8_000;
        let mut hs = Vec::new();
        for tid in 0..THREADS {
            let m = m.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(0xADD5, tid);
                let mut incs = 0u64;
                for _ in 0..OPS {
                    let k = 1 + r.below(KEYS);
                    if r.below(3) == 0 {
                        let cur = m.get(k);
                        let next = cur.unwrap_or(0) + 1;
                        if m.compare_exchange(k, cur, Some(next)).is_ok() {
                            incs += 1;
                        }
                    } else {
                        m.fetch_add(k, 1);
                        incs += 1;
                    }
                }
                incs
            }));
        }
        let total: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
        let sum: u64 = (1..=KEYS).map(|k| m.get(k).unwrap_or(0)).sum();
        assert_eq!(sum, total, "{}: lost/duplicated increments", kind.name());
    }
}

#[test]
fn concurrent_cmpex_lease_has_one_owner() {
    // Lease protocol on one hot key: acquire = cmpex(None -> owner),
    // release = cmpex(owner -> None). At most one thread may ever hold
    // the lease, and every successful acquire must see its own value.
    let m: Arc<dyn ConcurrentMap> =
        Arc::from(MapKind::ShardedKCasRhMap { shards: 4 }.build(10));
    let held = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut hs = Vec::new();
    for tid in 1..=4u64 {
        let (m, held) = (m.clone(), held.clone());
        hs.push(std::thread::spawn(move || {
            let mut acquisitions = 0u64;
            for _ in 0..20_000 {
                if m.compare_exchange(9, None, Some(tid)).is_ok() {
                    let other =
                        held.swap(tid, std::sync::atomic::Ordering::SeqCst);
                    assert_eq!(other, 0, "lease held by {other} and {tid}");
                    assert_eq!(m.get(9), Some(tid), "lease value torn");
                    held.store(0, std::sync::atomic::Ordering::SeqCst);
                    assert_eq!(
                        m.compare_exchange(9, Some(tid), None),
                        Ok(()),
                        "owner failed to release"
                    );
                    acquisitions += 1;
                }
            }
            acquisitions
        }));
    }
    let total: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total > 0, "no thread ever acquired the lease");
    assert_eq!(m.get(9), None);
}

#[test]
fn duplicate_insert_overwrites_value_everywhere() {
    for kind in MapKind::all() {
        let m = kind.build(8);
        assert_eq!(m.insert(42, 1), None, "{}", kind.name());
        assert_eq!(m.insert(42, 2), Some(1), "{}", kind.name());
        assert_eq!(m.insert(42, 3), Some(2), "{}", kind.name());
        assert_eq!(m.get(42), Some(3), "{}", kind.name());
        assert_eq!(m.len_quiesced(), 1, "{}", kind.name());
        assert_eq!(m.remove(42), Some(3), "{}", kind.name());
        assert_eq!(m.get(42), None, "{}", kind.name());
    }
}

/// The paper's Fig. 5 reader/remover race, map-flavoured and pushed
/// through the sharded facade: stable keys (whose value encodes the
/// key) must never be observed absent or paired with another key's
/// value while churn keys force backward shifts around them.
#[test]
fn fig5_get_after_remove_race_sharded_map() {
    let m: Arc<dyn ConcurrentMap> =
        Arc::from(MapKind::ShardedKCasRhMap { shards: 4 }.build(9));
    const CHURN: u64 = 60;
    for k in 1..=CHURN + 30 {
        m.insert(k, k * 7);
    }
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut hs = Vec::new();
    for tid in 0..2u64 {
        let (m, stop) = (m.clone(), stop.clone());
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0x55, tid);
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let k = 1 + r.below(CHURN);
                m.remove(k);
                m.insert(k, k * 7);
            }
        }));
    }
    for tid in 0..4u64 {
        let (m, stop) = (m.clone(), stop.clone());
        hs.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0x56, tid);
            for _ in 0..25_000 {
                let k = CHURN + 1 + r.below(30);
                match m.get(k) {
                    Some(v) => assert_eq!(v, k * 7, "torn pair for {k}"),
                    None => panic!("Fig. 5 race: stable key {k} absent"),
                }
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    m.check_invariant_quiesced().unwrap();
}

/// `apply_batch` replies must match op-by-op application, in op order,
/// for every map kind — random batches with repeated keys (so the
/// sharded grouping's same-key ordering is exercised).
#[test]
fn apply_batch_matches_op_by_op_everywhere() {
    for kind in MapKind::all() {
        let batched = kind.build(9);
        let serial = kind.build(9);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        let mut rng = Rng::new(0xBB);
        for round in 0..30 {
            let n = 1 + rng.below(48) as usize;
            let ops: Vec<MapOp> = (0..n)
                .map(|_| {
                    let k = 1 + rng.below(64);
                    match rng.below(5) {
                        0 => MapOp::Insert(k, rng.below(500)),
                        1 => MapOp::Remove(k),
                        2 => MapOp::GetOrInsert(k, rng.below(500)),
                        3 => MapOp::FetchAdd(k, rng.below(50)),
                        _ => MapOp::Get(k),
                    }
                })
                .collect();
            let got = apply_batch(batched.as_ref(), &ops);
            let want: Vec<MapReply> = ops
                .iter()
                .map(|&op| match op {
                    MapOp::Get(k) => {
                        assert_eq!(serial.get(k), oracle.get(&k).copied());
                        MapReply::Value(serial.get(k))
                    }
                    MapOp::Insert(k, v) => {
                        assert_eq!(
                            oracle.insert(k, v),
                            serial.get(k),
                            "oracle drift"
                        );
                        MapReply::Prev(serial.insert(k, v))
                    }
                    MapOp::Remove(k) => {
                        assert_eq!(oracle.remove(&k), serial.get(k));
                        MapReply::Removed(serial.remove(k))
                    }
                    MapOp::GetOrInsert(k, v) => {
                        let cur = oracle.get(&k).copied();
                        if cur.is_none() {
                            oracle.insert(k, v);
                        }
                        MapReply::Existing(serial.get_or_insert(k, v))
                    }
                    MapOp::FetchAdd(k, d) => {
                        let cur = oracle.get(&k).copied();
                        oracle.insert(k, cur.unwrap_or(0) + d);
                        MapReply::Added(serial.fetch_add(k, d))
                    }
                    MapOp::CmpEx(..) => {
                        unreachable!("this batch mix generates no CmpEx")
                    }
                })
                .collect();
            assert_eq!(got, want, "{} round {round}", kind.name());
        }
        assert_eq!(
            batched.len_quiesced(),
            serial.len_quiesced(),
            "{}",
            kind.name()
        );
    }
}

#[test]
fn server_round_trip_and_key_validation() {
    with_all_backends(
        || Arc::from(MapKind::ShardedKCasRhMap { shards: 4 }.build(12)),
        |backend, addr, map| {
            let mut c = Client::connect(addr).unwrap();

            // Single ops.
            assert_eq!(c.request_line("P 10 100").unwrap(), "-", "{backend}");
            assert_eq!(c.request_line("P 10 101").unwrap(), "100");
            assert_eq!(c.request_line("G 10").unwrap(), "101");
            assert_eq!(c.request_line("D 10").unwrap(), "101");
            assert_eq!(c.request_line("G 10").unwrap(), "-");

            // Satellite regression: out-of-range keys must get ERR,
            // not a connection-killing check_key panic — and the
            // connection must keep serving afterwards.
            let big = MAX_KEY + 1;
            assert_eq!(
                c.request_line(&format!("P {big} 1")).unwrap(),
                "ERR key out of range"
            );
            assert_eq!(
                c.request_line(&format!("G {big}")).unwrap(),
                "ERR key out of range"
            );
            assert_eq!(c.request_line("G 0").unwrap(), "ERR key out of range");
            assert_eq!(c.request_line("A 5").unwrap(), "ERR bad request");
            assert_eq!(c.request_line("B 0").unwrap(), "ERR bad batch size");
            assert_eq!(c.request_line("P 5 5").unwrap(), "-");

            // Batch frame, including a same-key dependency chain.
            let replies = c
                .batch(&[
                    MapOp::Insert(7, 70),
                    MapOp::Get(7),
                    MapOp::Insert(7, 71),
                    MapOp::Remove(7),
                    MapOp::Get(7),
                    MapOp::Get(5),
                ])
                .unwrap();
            assert_eq!(
                replies,
                vec![None, Some(70), Some(70), Some(71), None, Some(5)],
                "{backend}"
            );

            // A batch containing one bad op is rejected as a unit:
            // nothing applied, one ERR line, stream still in sync.
            let err = c
                .batch(&[MapOp::Insert(3, 30), MapOp::Get(big), MapOp::Get(3)])
                .unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
            assert_eq!(
                c.request_line("G 3").unwrap(),
                "-",
                "{backend}: bad batch was applied"
            );

            assert_eq!(map.len_quiesced(), 1, "{backend}"); // only key 5
        },
    );
}

#[test]
fn server_conditional_verbs_round_trip() {
    with_all_backends(
        || Arc::from(MapKind::ShardedKCasRhMap { shards: 4 }.build(12)),
        |backend, addr, map| {
            let mut c = Client::connect(addr).unwrap();

            // Lease flow: acquire, contended acquire, release.
            assert_eq!(c.request_line("C 7 - 1").unwrap(), "OK", "{backend}");
            assert_eq!(c.request_line("C 7 - 2").unwrap(), "!1");
            assert_eq!(c.request_line("C 7 2 -").unwrap(), "!1");
            assert_eq!(c.request_line("C 7 1 -").unwrap(), "OK");
            assert_eq!(c.request_line("C 7 - -").unwrap(), "OK");

            // Counter flow: fetch_add from absent, then get-or-insert.
            assert_eq!(c.request_line("A 9 5").unwrap(), "-");
            assert_eq!(c.request_line("A 9 2").unwrap(), "5");
            assert_eq!(c.request_line("G 9").unwrap(), "7");
            assert_eq!(c.request_line("U 9 100").unwrap(), "7");
            assert_eq!(c.request_line("U 11 100").unwrap(), "-");

            // Validation at the protocol boundary.
            assert_eq!(
                c.request_line(&format!("C {} - 1", MAX_KEY + 1)).unwrap(),
                "ERR key out of range"
            );
            assert_eq!(c.request_line("C 7 x 1").unwrap(), "ERR bad request");
            assert_eq!(c.request_line("A 7").unwrap(), "ERR bad request");

            // Typed batch round trip with a same-key dependency chain.
            let replies = c
                .batch_typed(&[
                    MapOp::CmpEx(3, None, Some(30)),
                    MapOp::FetchAdd(3, 4),
                    MapOp::CmpEx(3, Some(34), Some(35)),
                    MapOp::CmpEx(3, Some(34), Some(36)),
                    MapOp::GetOrInsert(3, 0),
                    MapOp::CmpEx(3, Some(35), None),
                    MapOp::Get(3),
                ])
                .unwrap();
            assert_eq!(
                replies,
                vec![
                    MapReply::CmpEx(Ok(())),
                    MapReply::Added(Some(30)),
                    MapReply::CmpEx(Ok(())),
                    MapReply::CmpEx(Err(Some(35))),
                    MapReply::Existing(Some(35)),
                    MapReply::CmpEx(Ok(())),
                    MapReply::Value(None),
                ],
                "{backend}"
            );
            assert_eq!(map.len_quiesced(), 2, "{backend}"); // keys 9, 11
        },
    );
}

#[test]
fn server_pipelined_frames_reply_in_order() {
    with_all_backends(
        || Arc::from(MapKind::KCasRhMap.build(12)),
        |backend, addr, _map| {
            let mut c = Client::connect(addr).unwrap();
            const FRAMES: u64 = 64;
            // Stream all frames without reading a single reply...
            for i in 1..=FRAMES {
                c.send_frame(&[MapOp::Insert(i, i * 10), MapOp::Get(i)])
                    .unwrap();
            }
            // ...then collect the replies in frame order.
            for i in 1..=FRAMES {
                let replies = c.read_batch_reply(2).unwrap();
                assert_eq!(
                    replies,
                    vec![None, Some(i * 10)],
                    "{backend} frame {i}"
                );
            }
        },
    );
}

/// Overfilling the table is a *capacity* failure, not a protocol one:
/// the apply stage must contain the table's "map is full" panic,
/// reply `ERR server error`, and close the connection — never die
/// reply-less mid-protocol (the panic-DoS shape the key-range guard
/// already covers for out-of-range keys).
#[test]
fn server_survives_full_table_with_error_reply() {
    with_all_backends(
        || Arc::from(MapKind::KCasRhMap.build(4)), // 16 buckets
        |backend, addr, _map| {
            let mut c = Client::connect(addr).unwrap();
            let mut saw_server_err = false;
            for k in 1..=40u64 {
                match c.request_line(&format!("P {k} 1")) {
                    Ok(reply) if reply == "ERR server error" => {
                        saw_server_err = true;
                        break;
                    }
                    Ok(reply) => assert_eq!(reply, "-", "{backend} key {k}"),
                    Err(e) => panic!(
                        "{backend}: connection died reply-less at key {k}: {e}"
                    ),
                }
            }
            assert!(
                saw_server_err,
                "{backend}: overfull table never reported ERR"
            );
            // The failed connection was dropped; the server still
            // accepts new clients (reads against the full table work).
            let mut c2 = Client::connect(addr).unwrap();
            assert_eq!(c2.request_line("G 1").unwrap(), "1", "{backend}");
        },
    );
}

#[test]
fn server_concurrent_clients_mixed_batches() {
    with_all_backends(
        || Arc::from(MapKind::ShardedKCasRhMap { shards: 4 }.build(12)),
        |backend, addr, map| {
            let mut hs = Vec::new();
            for tid in 0..4u64 {
                hs.push(std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let base = 1 + tid * 10_000;
                    // Disjoint key ranges: deterministic final state.
                    for chunk in 0..25u64 {
                        let ops: Vec<MapOp> = (0..8)
                            .map(|j| {
                                let k = base + chunk * 8 + j;
                                MapOp::Insert(k, k)
                            })
                            .collect();
                        let replies = c.batch(&ops).unwrap();
                        assert!(replies.iter().all(|v| v.is_none()));
                    }
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(map.len_quiesced(), 4 * 200, "{backend}");
        },
    );
}
