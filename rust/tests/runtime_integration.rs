//! Runtime integration: load the AOT artifacts through PJRT and verify
//! the three-layer stack end to end (Pallas kernel == JAX ref == Rust).
//!
//! Requires `make artifacts` (skipped with a loud message otherwise, so
//! `cargo test` stays runnable in a fresh checkout).

use crh::runtime::{artifacts_dir, Engine};
use crh::util::hash::splitmix64;

fn engine_or_skip() -> Option<Engine> {
    let dir = artifacts_dir();
    if !dir.join("MANIFEST.txt").exists() {
        eprintln!(
            "SKIP: artifacts not built ({}); run `make artifacts`",
            dir.display()
        );
        return None;
    }
    Some(Engine::load(&dir).expect("artifacts present but failed to load"))
}

#[test]
fn golden_vectors_bit_exact() {
    let Some(e) = engine_or_skip() else { return };
    let n = e.verify_golden(&artifacts_dir()).unwrap();
    assert!(n >= 64, "suspiciously few golden vectors: {n}");
}

#[test]
fn hash_batch_matches_rust_mixer() {
    let Some(e) = engine_or_skip() else { return };
    let b = e.manifest.hash_batch;
    let keys: Vec<i64> = (0..b as i64).map(|i| i * 7919 - 12345).collect();
    let (hashes, buckets) = e.hash_batch(&keys).unwrap();
    let mask = (1u64 << e.manifest.size_log2) - 1;
    for (i, &k) in keys.iter().enumerate() {
        let want = splitmix64(k as u64);
        assert_eq!(hashes[i] as u64, want, "hash mismatch at {i}");
        assert_eq!(buckets[i] as u64, want & mask, "bucket mismatch at {i}");
    }
}

#[test]
fn hash_stream_handles_ragged_tails() {
    let Some(e) = engine_or_skip() else { return };
    let keys: Vec<i64> = (0..1000).map(|i| i * 31 + 7).collect();
    let out = e.hash_stream(&keys).unwrap();
    assert_eq!(out.len(), keys.len());
    for (i, &k) in keys.iter().enumerate() {
        assert_eq!(out[i] as u64, splitmix64(k as u64));
    }
}

#[test]
fn probe_stats_matches_rust_computation() {
    let Some(e) = engine_or_skip() else { return };
    // Build a real Robin Hood table and compare the AOT analytics with
    // a plain Rust fold over the same snapshot.
    use crh::maps::{ConcurrentSet, TableKind};
    let t = TableKind::KCasRobinHood.build(12);
    for k in 1..=2800u64 {
        t.add(k);
    }
    let snap = t.dfb_snapshot();
    let stats = e.probe_stats(&snap).unwrap();

    let occ: Vec<i64> =
        snap.iter().filter(|&&d| d >= 0).map(|&d| d as i64).collect();
    let count = occ.len() as i64;
    let mean = occ.iter().sum::<i64>() as f64 / count as f64;
    let var = occ
        .iter()
        .map(|&d| (d as f64 - mean).powi(2))
        .sum::<f64>()
        / count as f64;
    assert_eq!(stats.count, count);
    assert!((stats.mean - mean).abs() < 1e-9, "{} vs {mean}", stats.mean);
    assert!((stats.var - var).abs() < 1e-6, "{} vs {var}", stats.var);
    assert_eq!(stats.max as i64, *occ.iter().max().unwrap());
    assert_eq!(stats.hist.iter().sum::<i64>(), count);
}

#[test]
fn probe_stats_empty_snapshot() {
    let Some(e) = engine_or_skip() else { return };
    let stats = e.probe_stats(&vec![-1; 100]).unwrap();
    assert_eq!(stats.count, 0);
    assert_eq!(stats.hist.iter().sum::<i64>(), 0);
}

#[test]
fn manifest_shapes_sane() {
    let Some(e) = engine_or_skip() else { return };
    assert!(e.manifest.hash_batch.is_power_of_two());
    assert!(e.manifest.stats_batch.is_power_of_two());
    assert!(e.manifest.max_dfb >= 16);
    assert!(e.manifest.size_log2 >= 10);
}

#[test]
fn celis_probe_length_theory_via_engine() {
    // The paper's §2.2 claim, measured through the full stack: mean DFB
    // stays O(1) even at 80% load factor.
    let Some(e) = engine_or_skip() else { return };
    use crh::maps::{ConcurrentSet, TableKind};
    let t = TableKind::KCasRobinHood.build(14);
    let n = ((1 << 14) as f64 * 0.8) as u64;
    for k in 1..=n {
        t.add(k);
    }
    let stats = e.probe_stats(&t.dfb_snapshot()).unwrap();
    assert_eq!(stats.count as u64, n);
    assert!(stats.mean < 4.0, "mean DFB {} at LF 0.8", stats.mean);
    // And the histogram mass is concentrated at small distances.
    let first4: i64 = stats.hist.iter().take(4).sum();
    assert!(first4 as f64 / n as f64 > 0.7);
}
