//! Bench: **Figure 14** (extension) — throughput of the batched KV
//! pipeline (`service::batch`) across batch size x thread count,
//! against the unbatched op-by-op baseline.
//!
//! ```sh
//! cargo bench --bench fig14_batching            # paper-scale-ish
//! cargo bench --bench fig14_batching -- --quick # CI smoke
//! ```
//! Tunables: CRH_BENCH_SIZE_LOG2, CRH_BENCH_MS, CRH_BENCH_THREADS
//! (comma list), CRH_BENCH_BATCHES (comma list), CRH_BENCH_MAP
//! (a MapKind spec, e.g. `sharded-kcas-rh-map:16`).

mod common;

use crh::coordinator::{fig14_batching, ExpOpts};
use crh::maps::MapKind;

fn main() {
    let quick = common::quick();
    let mut opts = ExpOpts {
        size_log2: common::env_u32("SIZE_LOG2", if quick { 16 } else { 22 }),
        duration_ms: common::env_u64("MS", if quick { 100 } else { 500 }),
        pin: true,
        reps: common::env_u32("REPS", if quick { 1 } else { 3 }),
        ..ExpOpts::default()
    };
    if let Ok(ts) = std::env::var("CRH_BENCH_THREADS") {
        opts.threads = ts.split(',').filter_map(|x| x.parse().ok()).collect();
    } else if quick {
        opts.threads = vec![1, 2];
    }
    let batches: Vec<usize> = match std::env::var("CRH_BENCH_BATCHES") {
        Ok(s) => s.split(',').filter_map(|x| x.parse().ok()).collect(),
        Err(_) => vec![1, 8, 64],
    };
    let map = match std::env::var("CRH_BENCH_MAP") {
        Ok(s) => MapKind::parse(&s)
            .unwrap_or_else(|| panic!("unknown CRH_BENCH_MAP {s}")),
        Err(_) => MapKind::ShardedKCasRhMap { shards: 4 },
    };
    common::write_snapshot(&fig14_batching(&opts, map, &batches));
}
