//! Bench: **Figure 12** — throughput (ops/µs) vs thread count at 60%
//! and 80% load factor, light (10%) and heavy (20%) update rates —
//! where Robin Hood's high-load-factor tolerance shows.
//!
//! ```sh
//! cargo bench --bench fig12_scaling_high_lf [-- --quick]
//! ```

mod common;

use crh::coordinator::{fig12, ExpOpts};

fn main() {
    let quick = common::quick();
    let mut opts = ExpOpts {
        size_log2: common::env_u32("SIZE_LOG2", if quick { 16 } else { 22 }),
        duration_ms: common::env_u64("MS", if quick { 100 } else { 500 }),
        pin: true,
        reps: common::env_u32("REPS", if quick { 1 } else { 3 }),
        ..ExpOpts::default()
    };
    if let Ok(ts) = std::env::var("CRH_BENCH_THREADS") {
        opts.threads = ts.split(',').filter_map(|x| x.parse().ok()).collect();
    } else if quick {
        opts.threads = vec![1, 2];
    }
    common::write_snapshot(&fig12(&opts));
}
