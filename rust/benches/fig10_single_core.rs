//! Bench: **Figure 10** — single-core relative performance of every
//! hash table vs K-CAS Robin Hood across the paper's 8 workload
//! configurations (LF {20,40,60,80}% x updates {10,20}%).
//!
//! ```sh
//! cargo bench --bench fig10_single_core            # paper-scale-ish
//! cargo bench --bench fig10_single_core -- --quick # CI smoke
//! ```
//! Tunables: CRH_BENCH_SIZE_LOG2, CRH_BENCH_MS, CRH_BENCH_REPS.

mod common;

use crh::coordinator::{fig10, ExpOpts};

fn main() {
    let quick = common::quick();
    let opts = ExpOpts {
        size_log2: common::env_u32("SIZE_LOG2", if quick { 16 } else { 22 }),
        duration_ms: common::env_u64("MS", if quick { 100 } else { 500 }),
        threads: vec![1],
        pin: true,
        reps: common::env_u32("REPS", if quick { 1 } else { 3 }),
    };
    common::write_snapshot(&fig10(&opts));
}
