//! Shared plumbing for the custom bench harness (criterion is not
//! available in this offline environment; these are plain `main()`
//! benches registered with `harness = false`).

/// Env-var override helper: `CRH_BENCH_<NAME>`.
pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(format!("CRH_BENCH_{name}"))
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn env_u32(name: &str, default: u32) -> u32 {
    env_u64(name, default as u64) as u32
}

/// `--quick` (or CRH_BENCH_QUICK=1) runs a fast smoke-size pass.
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || env_u64("QUICK", 0) == 1
}

/// Write the run's `BENCH_<fig>.json` perf-trajectory snapshot when
/// enabled (`CRH_BENCH_JSON=1` or a literal `--json` argument; see
/// `crh::bench::report`). A no-op otherwise, so every bench main can
/// call it unconditionally.
pub fn write_snapshot(report: &crh::bench::report::BenchReport) {
    let _ = crh::bench::report::write_if_enabled(report);
}
