//! Bench: **Figure 18** (extension) — multi-key transaction
//! throughput: SmallBank-style transfers (one debit + N-1 credits,
//! committed all-or-nothing) across commit engine (native one-K-CAS
//! commit vs OCC baseline vs 2PL baseline) x transaction size x
//! contention skew x thread count. Every native cell asserts
//! conservation of the account total — the atomicity witness.
//!
//! ```sh
//! cargo bench --bench fig18_txn            # paper-scale-ish
//! cargo bench --bench fig18_txn -- --quick # CI smoke
//! ```
//! Tunables: CRH_BENCH_SIZE_LOG2, CRH_BENCH_MS, CRH_BENCH_THREADS
//! (comma list), CRH_BENCH_SHARDS (comma list), CRH_BENCH_TXN_SIZES
//! (comma list of legs/transfer), CRH_BENCH_HOT_KEYS (comma list of
//! hot-account-set sizes).

mod common;

use crh::coordinator::{fig18_txn, ExpOpts};

fn main() {
    let quick = common::quick();
    let mut opts = ExpOpts {
        size_log2: common::env_u32("SIZE_LOG2", if quick { 14 } else { 18 }),
        duration_ms: common::env_u64("MS", if quick { 100 } else { 500 }),
        pin: true,
        // Flagged single-sample cells; 3 reps even in quick mode.
        reps: common::env_u32("REPS", 3),
        ..ExpOpts::default()
    };
    if let Ok(ts) = std::env::var("CRH_BENCH_THREADS") {
        opts.threads = ts.split(',').filter_map(|x| x.parse().ok()).collect();
    } else if quick {
        opts.threads = vec![1, 2];
    }
    let parse_list = |name: &str| -> Option<Vec<u64>> {
        std::env::var(format!("CRH_BENCH_{name}"))
            .ok()
            .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
    };
    // The acceptance gate runs the quick shape: shards >= 4 so native
    // commits genuinely span shard boundaries.
    let shards: Vec<u32> = parse_list("SHARDS")
        .map(|v| v.into_iter().map(|x| x as u32).collect())
        .unwrap_or_else(|| if quick { vec![4] } else { vec![1, 4, 16] });
    let txn_sizes: Vec<usize> = parse_list("TXN_SIZES")
        .map(|v| v.into_iter().map(|x| x as usize).collect())
        .unwrap_or_else(|| if quick { vec![2, 4] } else { vec![2, 4, 8] });
    let hot: Vec<u64> = parse_list("HOT_KEYS").unwrap_or_else(|| {
        if quick {
            vec![16, 1024]
        } else {
            vec![8, 64, 1024]
        }
    });
    common::write_snapshot(&fig18_txn(&opts, &shards, &txn_sizes, &hot));
}
