//! Bench: **Figure 11** — throughput (ops/µs) vs thread count at 20%
//! and 40% load factor, light (10%) and heavy (20%) update rates.
//!
//! ```sh
//! cargo bench --bench fig11_scaling_low_lf [-- --quick]
//! ```
//! Tunables: CRH_BENCH_SIZE_LOG2, CRH_BENCH_MS, CRH_BENCH_REPS,
//! CRH_BENCH_THREADS (comma list). CRH_BENCH_JSON=1 (or `-- --json`)
//! writes the run as a BENCH_fig11.json snapshot.

mod common;

use crh::coordinator::{fig11, ExpOpts};

fn main() {
    let quick = common::quick();
    let mut opts = ExpOpts {
        size_log2: common::env_u32("SIZE_LOG2", if quick { 16 } else { 22 }),
        duration_ms: common::env_u64("MS", if quick { 100 } else { 500 }),
        pin: true,
        reps: common::env_u32("REPS", if quick { 1 } else { 3 }),
        ..ExpOpts::default()
    };
    if let Ok(ts) = std::env::var("CRH_BENCH_THREADS") {
        opts.threads = ts.split(',').filter_map(|x| x.parse().ok()).collect();
    } else if quick {
        opts.threads = vec![1, 2];
    }
    common::write_snapshot(&fig11(&opts));
}
