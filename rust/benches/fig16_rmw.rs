//! Bench: **Figure 16** (extension) — conditional read-modify-write
//! throughput under contention skew: the CAS-heavy counter workload
//! (70% `fetch_add`, 20% optimistic `compare_exchange`, 10% `get`)
//! across hot-set size x thread count, native single-K-CAS
//! conditionals vs the locked baseline. Every cell asserts the
//! committed-increment count equals the final counter sum.
//!
//! ```sh
//! cargo bench --bench fig16_rmw            # paper-scale-ish
//! cargo bench --bench fig16_rmw -- --quick # CI smoke
//! ```
//! Tunables: CRH_BENCH_SIZE_LOG2, CRH_BENCH_MS, CRH_BENCH_THREADS
//! (comma list), CRH_BENCH_HOT_KEYS (comma list of hot-set sizes),
//! CRH_BENCH_MAPS (comma list of MapKind specs).

mod common;

use crh::coordinator::{fig16_rmw, ExpOpts};
use crh::maps::MapKind;

fn main() {
    let quick = common::quick();
    let mut opts = ExpOpts {
        size_log2: common::env_u32("SIZE_LOG2", if quick { 14 } else { 20 }),
        duration_ms: common::env_u64("MS", if quick { 100 } else { 500 }),
        pin: true,
        // Flagged single-sample cells; 3 reps even in quick mode.
        reps: common::env_u32("REPS", 3),
        ..ExpOpts::default()
    };
    if let Ok(ts) = std::env::var("CRH_BENCH_THREADS") {
        opts.threads = ts.split(',').filter_map(|x| x.parse().ok()).collect();
    } else if quick {
        opts.threads = vec![1, 2];
    }
    let hot_keys: Vec<u64> = match std::env::var("CRH_BENCH_HOT_KEYS") {
        Ok(s) => s.split(',').filter_map(|x| x.parse().ok()).collect(),
        Err(_) => {
            if quick {
                vec![1, 256]
            } else {
                vec![1, 16, 256, 4096]
            }
        }
    };
    let maps: Vec<MapKind> = match std::env::var("CRH_BENCH_MAPS") {
        Ok(s) => s
            .split(',')
            .map(|x| {
                MapKind::parse(x)
                    .unwrap_or_else(|| panic!("unknown CRH_BENCH_MAPS entry {x}"))
            })
            .collect(),
        Err(_) => vec![
            MapKind::ShardedKCasRhMap { shards: 4 },
            MapKind::ShardedLockedLpMap { shards: 4 },
        ],
    };
    common::write_snapshot(&fig16_rmw(&opts, &maps, &hot_keys));
}
