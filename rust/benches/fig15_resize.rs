//! Bench: **Figure 15** (extension) — per-op latency during an
//! in-flight grow migration: the incremental two-generation engine
//! (`inc-resize-rh`) vs the quiescing epoch-RwLock rebuild
//! (`resizable-rh`), across thread count x grow threshold.
//!
//! ```sh
//! cargo bench --bench fig15_resize            # paper-scale-ish
//! cargo bench --bench fig15_resize -- --quick # CI smoke
//! ```
//! Tunables: CRH_BENCH_SIZE_LOG2, CRH_BENCH_MS, CRH_BENCH_REPS,
//! CRH_BENCH_THREADS (comma list), CRH_BENCH_GROW_ATS (comma list of
//! thresholds). CRH_BENCH_JSON=1 (or `-- --json`) writes the run as a
//! BENCH_fig15.json snapshot.

mod common;

use crh::coordinator::{fig15_resize, ExpOpts};

fn main() {
    let quick = common::quick();
    let mut opts = ExpOpts {
        size_log2: common::env_u32("SIZE_LOG2", if quick { 14 } else { 20 }),
        duration_ms: common::env_u64("MS", if quick { 100 } else { 500 }),
        pin: true,
        // These cells were the issue's flagged single-sample numbers;
        // default to 3 reps even in quick mode (median is printed,
        // min/median/max land in the snapshot).
        reps: common::env_u32("REPS", 3),
        ..ExpOpts::default()
    };
    if let Ok(ts) = std::env::var("CRH_BENCH_THREADS") {
        opts.threads = ts.split(',').filter_map(|x| x.parse().ok()).collect();
    } else if quick {
        opts.threads = vec![1, 2];
    }
    let grow_ats: Vec<f64> = match std::env::var("CRH_BENCH_GROW_ATS") {
        Ok(s) => s.split(',').filter_map(|x| x.parse().ok()).collect(),
        Err(_) => {
            if quick {
                vec![0.7]
            } else {
                vec![0.7, 0.85]
            }
        }
    };
    common::write_snapshot(&fig15_resize(&opts, &grow_ats));
}
