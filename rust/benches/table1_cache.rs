//! Bench: **Table 1** — cache misses relative to K-CAS Robin Hood
//! (single core) across the 8 workload configurations, via the
//! set-associative cache simulator + per-table trace models
//! (PAPI substitute; see DESIGN.md substitution #2).
//!
//! ```sh
//! cargo bench --bench table1_cache [-- --quick]
//! ```
//! Tunables: CRH_BENCH_SIZE_LOG2 (default 22), CRH_BENCH_OPS.

mod common;

use crh::coordinator::table1;

fn main() {
    let quick = common::quick();
    let size = common::env_u32("SIZE_LOG2", if quick { 18 } else { 22 });
    let ops = common::env_u64("OPS", if quick { 100_000 } else { 3_000_000 });
    common::write_snapshot(&table1(size, ops));
}
