//! Bench: **Figure 13** (extension) — throughput of the generic
//! `Sharded<T>` facade across shard count x thread count at 60% and 80%
//! load factor, against the unsharded K-CAS Robin Hood baseline.
//!
//! ```sh
//! cargo bench --bench fig13_sharding            # paper-scale-ish
//! cargo bench --bench fig13_sharding -- --quick # CI smoke
//! ```
//! Tunables: CRH_BENCH_SIZE_LOG2, CRH_BENCH_MS, CRH_BENCH_THREADS
//! (comma list), CRH_BENCH_SHARDS (comma list).

mod common;

use crh::coordinator::{fig13_sharding, ExpOpts};
use crh::maps::TableKind;

fn main() {
    let quick = common::quick();
    let mut opts = ExpOpts {
        size_log2: common::env_u32("SIZE_LOG2", if quick { 16 } else { 22 }),
        duration_ms: common::env_u64("MS", if quick { 100 } else { 500 }),
        pin: true,
        reps: common::env_u32("REPS", if quick { 1 } else { 3 }),
        ..ExpOpts::default()
    };
    if let Ok(ts) = std::env::var("CRH_BENCH_THREADS") {
        opts.threads = ts.split(',').filter_map(|x| x.parse().ok()).collect();
    } else if quick {
        opts.threads = vec![1, 2];
    }
    let shards: Vec<u32> = match std::env::var("CRH_BENCH_SHARDS") {
        Ok(s) => s.split(',').filter_map(|x| x.parse().ok()).collect(),
        Err(_) => TableKind::SHARD_SWEEP.to_vec(),
    };
    common::write_snapshot(&fig13_sharding(&opts, &shards));
}
