//! Bench: **Figure 17** (extension) — KV front-end comparison over
//! real TCP across the three-backend matrix: the thread-per-connection
//! pipeline (two OS threads per socket), the epoll event loop (fixed
//! worker pool, ops batched across ready sockets into one
//! `apply_batch_hashed` per wake-up), and the io_uring completion-ring
//! backend (same wake-batch structure, but one `io_uring_enter` per
//! wake in each direction instead of one `read`/`write` per
//! connection), swept across connection count x event-loop worker
//! count plus a high-connection-count churn cell.
//!
//! Before any throughput is reported, every backend must answer a
//! fixed protocol trace (all verbs, protocol errors, batch frames,
//! frames split across read boundaries) **byte-identically** — the CI
//! smoke gate. Quick mode additionally asserts (a) the event loop is
//! at least as fast as thread-per-connection at 64 connections, and
//! (b) at 256 connections the uring backend's server-side
//! syscalls-per-op is measurably below the epoll reactor's — a count
//! comparison, immune to CI-runner timing noise.
//!
//! ```sh
//! cargo bench --bench fig17_frontend                    # full sweep
//! cargo bench --bench fig17_frontend -- --quick         # CI smoke
//! cargo bench --bench fig17_frontend -- --quick --backend uring
//! ```
//! `--backend a,b` (or CRH_BENCH_BACKEND) filters the matrix; a
//! uring-only run on a kernel without io_uring skips with a notice
//! instead of silently measuring the epoll fallback. Tunables:
//! CRH_BENCH_SIZE_LOG2, CRH_BENCH_CONNS (comma list),
//! CRH_BENCH_WORKERS (comma list), CRH_BENCH_FRAMES, CRH_BENCH_BATCH,
//! CRH_BENCH_REPS. CRH_BENCH_JSON=1 (or `-- --json`) writes the run
//! as a BENCH_fig17.json snapshot.

mod common;

use crh::coordinator::{fig17_frontend, fig17_pair, fig17_syscalls};
use crh::service::Backend;

fn env_list(name: &str, default: Vec<usize>) -> Vec<usize> {
    match std::env::var(format!("CRH_BENCH_{name}")) {
        Ok(s) => {
            let v: Vec<usize> =
                s.split(',').filter_map(|x| x.parse().ok()).collect();
            if v.is_empty() {
                default
            } else {
                v
            }
        }
        Err(_) => default,
    }
}

fn arg_value(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn main() {
    let quick = common::quick();
    let size_log2 = common::env_u32("SIZE_LOG2", 16);
    let conns = env_list(
        "CONNS",
        if quick { vec![8, 64] } else { vec![16, 64, 256] },
    );
    let workers =
        env_list("WORKERS", if quick { vec![2] } else { vec![1, 2, 4] });
    let frames = common::env_u64(
        "FRAMES",
        if quick { 150 } else { 2000 },
    ) as usize;
    let batch = common::env_u64("BATCH", 8) as usize;
    // Flagged single-sample cells; 3 reps (fresh server+map per rep)
    // even in quick mode.
    let reps = common::env_u32("REPS", 3);
    let backends: Vec<Backend> = match arg_value("--backend")
        .or_else(|| std::env::var("CRH_BENCH_BACKEND").ok())
    {
        Some(s) => s
            .split(',')
            .map(|b| {
                Backend::parse(b)
                    .unwrap_or_else(|| panic!("unknown backend {b}"))
            })
            .collect(),
        None => Backend::ALL.to_vec(),
    };
    let uring_live = crh::service::uring::uring_frontend_available();
    if backends == [Backend::Uring] && !uring_live {
        // The CI uring lane on a kernel without io_uring: running the
        // sweep would measure the epoll fallback under a uring label.
        println!(
            "fig17_frontend: kernel lacks io_uring — uring lane SKIPPED"
        );
        return;
    }

    common::write_snapshot(&fig17_frontend(
        size_log2, &conns, &workers, frames, batch, reps, &backends,
    ));

    if !quick {
        return;
    }
    if backends.contains(&Backend::Threads)
        && backends.contains(&Backend::Reactor)
    {
        // The original acceptance gate: at 64 connections the event
        // loop must at least match thread-per-connection throughput.
        // Timing noise on small shared CI runners can make two healthy
        // backends measure within a few percent of each other, so the
        // strict comparison gets retries at longer measurements, and
        // only a clear loss (below 90% on the final, longest run)
        // fails the job — a real regression (the event loop collapsing
        // under 128 competing threads' worth of load) shows up as a
        // large ratio, not a coin flip.
        let w = workers[0];
        let (mut threaded, mut epoll) =
            fig17_pair(size_log2, 64, w, frames, batch);
        for scale in [4usize, 8] {
            if epoll >= threaded {
                break;
            }
            eprintln!(
                "retrying 64-conn gate at {scale}x frames (epoll {:.0} < \
                 threaded {:.0} ops/s)",
                epoll, threaded
            );
            (threaded, epoll) =
                fig17_pair(size_log2, 64, w, scale * frames, batch);
        }
        assert!(
            epoll >= 0.9 * threaded,
            "epoll backend clearly slower than thread-per-conn at 64 \
             connections: {epoll:.0} vs {threaded:.0} ops/s"
        );
        println!(
            "quick gate OK at 64 connections: epoll {:.0} ops/s vs \
             thread-per-conn {:.0} ops/s ({:.2}x)",
            epoll,
            threaded,
            epoll / threaded
        );
    }
    if backends.contains(&Backend::Uring) {
        if !uring_live {
            println!(
                "syscalls-per-op gate SKIPPED: kernel lacks io_uring"
            );
            return;
        }
        // The io_uring acceptance gate, on syscall *counts* rather
        // than throughput: at 256 connections the ring backend must
        // spend measurably fewer syscalls per op than the epoll
        // reactor — that is the entire point of the backend, and
        // counts don't flake with runner load the way timings do.
        let gate_conns = 256usize;
        let w = workers[0];
        let (_, epoll_spo) = fig17_syscalls(
            Backend::Reactor,
            size_log2,
            gate_conns,
            w,
            frames,
            batch,
        );
        let (_, uring_spo) = fig17_syscalls(
            Backend::Uring,
            size_log2,
            gate_conns,
            w,
            frames,
            batch,
        );
        if !epoll_spo.is_finite() || !uring_spo.is_finite() {
            println!(
                "syscalls-per-op gate SKIPPED: metrics disabled \
                 (CRH_METRICS=0)"
            );
            return;
        }
        assert!(
            uring_spo < 0.8 * epoll_spo,
            "uring backend's syscalls-per-op not measurably below \
             epoll's at {gate_conns} connections: {uring_spo:.3} vs \
             {epoll_spo:.3}"
        );
        println!(
            "syscalls-per-op gate OK at {gate_conns} connections: uring \
             {uring_spo:.3} vs epoll {epoll_spo:.3} ({:.1}x fewer)",
            epoll_spo / uring_spo
        );
    }
}
