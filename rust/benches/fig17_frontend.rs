//! Bench: **Figure 17** (extension) — KV front-end comparison over
//! real TCP: the thread-per-connection pipeline (two OS threads per
//! socket) vs the epoll event loop (fixed worker pool, ops batched
//! across ready sockets into one `apply_batch_hashed` per wake-up),
//! swept across connection count x event-loop worker count.
//!
//! Before any throughput is reported, both backends must answer a
//! fixed protocol trace (all verbs, protocol errors, batch frames,
//! frames split across read boundaries) **byte-identically** — the CI
//! smoke gate. Quick mode additionally asserts the event loop is at
//! least as fast as thread-per-connection at 64 connections, where the
//! threaded backend is juggling 128 server threads.
//!
//! ```sh
//! cargo bench --bench fig17_frontend            # full sweep
//! cargo bench --bench fig17_frontend -- --quick # CI smoke
//! ```
//! Tunables: CRH_BENCH_SIZE_LOG2, CRH_BENCH_CONNS (comma list),
//! CRH_BENCH_WORKERS (comma list), CRH_BENCH_FRAMES, CRH_BENCH_BATCH,
//! CRH_BENCH_REPS. CRH_BENCH_JSON=1 (or `-- --json`) writes the run
//! as a BENCH_fig17.json snapshot.

mod common;

use crh::coordinator::{fig17_frontend, fig17_pair};

fn env_list(name: &str, default: Vec<usize>) -> Vec<usize> {
    match std::env::var(format!("CRH_BENCH_{name}")) {
        Ok(s) => {
            let v: Vec<usize> =
                s.split(',').filter_map(|x| x.parse().ok()).collect();
            if v.is_empty() {
                default
            } else {
                v
            }
        }
        Err(_) => default,
    }
}

fn main() {
    let quick = common::quick();
    let size_log2 = common::env_u32("SIZE_LOG2", 16);
    let conns = env_list(
        "CONNS",
        if quick { vec![8, 64] } else { vec![16, 64, 256] },
    );
    let workers =
        env_list("WORKERS", if quick { vec![2] } else { vec![1, 2, 4] });
    let frames = common::env_u64(
        "FRAMES",
        if quick { 150 } else { 2000 },
    ) as usize;
    let batch = common::env_u64("BATCH", 8) as usize;
    // Flagged single-sample cells; 3 reps (fresh server+map per rep)
    // even in quick mode.
    let reps = common::env_u32("REPS", 3);

    common::write_snapshot(&fig17_frontend(
        size_log2, &conns, &workers, frames, batch, reps,
    ));

    if quick {
        // The acceptance gate: at 64 connections the event loop must
        // at least match thread-per-connection throughput. Timing
        // noise on small shared CI runners can make two healthy
        // backends measure within a few percent of each other, so the
        // strict comparison gets retries at longer measurements, and
        // only a clear loss (below 90% on the final, longest run)
        // fails the job — a real regression (the event loop collapsing
        // under 128 competing threads' worth of load) shows up as a
        // large ratio, not a coin flip.
        let workers = workers[0];
        let (mut threaded, mut epoll) =
            fig17_pair(size_log2, 64, workers, frames, batch);
        for scale in [4usize, 8] {
            if epoll >= threaded {
                break;
            }
            eprintln!(
                "retrying 64-conn gate at {scale}x frames (epoll {:.0} < \
                 threaded {:.0} ops/s)",
                epoll, threaded
            );
            (threaded, epoll) =
                fig17_pair(size_log2, 64, workers, scale * frames, batch);
        }
        assert!(
            epoll >= 0.9 * threaded,
            "epoll backend clearly slower than thread-per-conn at 64 \
             connections: {epoll:.0} vs {threaded:.0} ops/s"
        );
        println!(
            "quick gate OK at 64 connections: epoll {:.0} ops/s vs \
             thread-per-conn {:.0} ops/s ({:.2}x)",
            epoll,
            threaded,
            epoll / threaded
        );
    }
}
